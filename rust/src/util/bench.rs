//! Hand-rolled micro-benchmark harness (replaces criterion in the offline
//! build). Each `rust/benches/*.rs` target uses `harness = false` and calls
//! into this module; results print as aligned tables and can be dumped as
//! JSON for EXPERIMENTS.md.

use std::time::Instant;

use super::stats::Summary;

/// Time `f` with `warmup` unmeasured runs and `samples` measured runs,
/// returning a Summary in **milliseconds**.
pub fn time_ms<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Summary {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut xs = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        xs.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Summary::of(&xs)
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept behind one name so benches read uniformly).
pub fn sink<T>(v: T) -> T {
    std::hint::black_box(v)
}

/// A fixed-width table printer for bench output that mirrors the paper's
/// table layout (rows = models/configs, columns = frameworks/devices).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for c in 0..ncol {
            w[c] = self.headers[c].len();
            for r in &self.rows {
                w[c] = w[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], w: &[usize], out: &mut String| {
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    out.push_str("  ");
                }
                out.push_str(cell);
                for _ in cell.len()..w[c] {
                    out.push(' ');
                }
            }
            out.push('\n');
        };
        line(&self.headers, &w, &mut out);
        let total: usize = w.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            line(r, &w, &mut out);
        }
        out
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ms_counts_samples() {
        let s = time_ms(1, 5, || {
            sink(2u64.pow(10));
        });
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Model", "ms"]);
        t.row(vec!["ResNet-50".into(), "36".into()]);
        t.row(vec!["VGG".into(), "117".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("Model"));
        assert!(lines[2].starts_with("ResNet-50"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
