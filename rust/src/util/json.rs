//! Minimal JSON value type with a writer and a recursive-descent parser.
//! Replaces serde_json in the offline build. Used for artifact metadata
//! (`artifacts/meta.json`, accuracy tables exported by python/compile) and
//! bench result dumps. Supports exactly the JSON we produce: objects,
//! arrays, strings, finite numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept sorted (BTreeMap) so output is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error message on malformed input.
    pub fn parse(input: &str) -> Result<Json, String> {
        let bytes = input.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar; since input is &str it is valid UTF-8.
                    let rest = std::str::from_utf8(&self.b[self.i..]).unwrap();
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("name", Json::str("resnet50")),
            ("flops", Json::num(8.2e9)),
            ("layers", Json::arr(vec![Json::num(1.0), Json::num(2.0)])),
            ("pruned", Json::Bool(true)),
            ("note", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_whitespace_and_escapes() {
        let j = Json::parse(" { \"a\\n\" : [ 1 , -2.5e1 , \"x\\\"y\" ] } ").unwrap();
        let a = j.get("a\n").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-25.0));
        assert_eq!(a[2].as_str(), Some("x\"y"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_render_without_dot() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse("\"\\u0041\"").unwrap();
        assert_eq!(j.as_str(), Some("A"));
    }
}
