//! Summary statistics used by the bench harness and the scheduler simulator
//! (Table 5 reports mean ± std per module).

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary of a sample: mean, std, min, max, p50, p95.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on empty input.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: s[0],
            max: *s.last().unwrap(),
            p50: percentile_sorted(&s, 0.50),
            p95: percentile_sorted(&s, 0.95),
        }
    }
}

/// Percentile (nearest-rank interpolation) over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[3.0; 10]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p95, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile_sorted(&s, 0.5) - 2.5).abs() < 1e-12);
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 1.0), 4.0);
    }
}
