//! Deterministic PRNG (xorshift64* core + helpers). Replaces `rand` in the
//! offline build; determinism is load-bearing for reproducible experiments —
//! every bench seeds its own `Rng` so tables are identical across runs.

/// A small, fast, deterministic PRNG (xorshift64*).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a PRNG from a seed. Seed 0 is remapped (xorshift requires a
    /// non-zero state).
    pub fn new(seed: u64) -> Self {
        let state = if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed };
        Rng { state }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Modulo bias is irrelevant for our n << 2^64 uses.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform i64 in [lo, hi).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std, as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Vector of standard-normal f32 values (DNN weight init).
    pub fn normal_vec(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(mean, std)).collect()
    }

    /// Split off an independent stream (for parallel substructures).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(5);
        let mut b = a.fork();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
