//! Minimal property-based testing helper (replaces `proptest`, unavailable
//! offline). A property is a closure over a seeded [`Rng`](super::rng::Rng);
//! the runner executes it for N deterministic cases and reports the failing
//! seed so a case can be replayed as a plain unit test. No shrinking — cases
//! are kept small by construction instead.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit the xla rpath)
//! use xgen::util::proptest_lite::forall;
//! forall("sort is idempotent", 64, |rng| {
//!     let mut v: Vec<u32> = (0..rng.below(20)).map(|_| rng.next_u32() % 100).collect();
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Rng;

/// Base seed; change to re-explore the case space globally.
pub const BASE_SEED: u64 = 0xC0C0_91E5_0000_0001;

/// Run `prop` for `cases` deterministic seeds. On panic, re-raises with the
/// case index and seed in the message.
pub fn forall<F: FnMut(&mut Rng)>(name: &str, cases: u32, mut prop: F) {
    for case in 0..cases {
        let seed = BASE_SEED ^ ((case as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = r {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Convenience generators used across module property tests.
pub mod gen {
    use super::Rng;

    /// Random vec of f32 in [-scale, scale] with length in [min_len, max_len].
    pub fn f32_vec(rng: &mut Rng, min_len: usize, max_len: usize, scale: f32) -> Vec<f32> {
        let n = min_len + rng.below(max_len - min_len + 1);
        (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
    }

    /// Random dims, each in [1, max_dim].
    pub fn dims(rng: &mut Rng, rank: usize, max_dim: usize) -> Vec<usize> {
        (0..rank).map(|_| 1 + rng.below(max_dim)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        forall("true", 16, |rng| {
            let x = rng.below(10);
            assert!(x < 10);
        });
    }

    #[test]
    fn reports_failing_case() {
        let r = std::panic::catch_unwind(|| {
            forall("always-fails", 4, |_rng| {
                panic!("boom");
            });
        });
        let e = r.unwrap_err();
        let msg = e.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("case 0"), "{msg}");
    }

    #[test]
    fn generators_respect_bounds() {
        forall("gen bounds", 32, |rng| {
            let v = gen::f32_vec(rng, 1, 8, 2.0);
            assert!((1..=8).contains(&v.len()));
            assert!(v.iter().all(|x| x.abs() <= 2.0));
            let d = gen::dims(rng, 3, 5);
            assert!(d.iter().all(|&x| (1..=5).contains(&x)));
        });
    }
}
