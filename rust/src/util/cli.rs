//! Tiny argv parser (replaces `clap` in the offline build).
//!
//! Grammar: `xgen <command> [--flag] [--key value] [positional...]`.
//! `--key=value` is also accepted.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, flags, key→value options, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.options.insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    // Heuristic: `--key value` when the next token is not a flag.
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn command_and_options() {
        // NOTE the documented ambiguity: `--verbose x.hlo` would bind x.hlo
        // as the option value, so boolean flags go last or use `=`.
        let a = parse(&["compile", "--model", "resnet50", "--opt=full", "x.hlo", "--verbose"]);
        assert_eq!(a.command, "compile");
        assert_eq!(a.opt("model"), Some("resnet50"));
        assert_eq!(a.opt("opt"), Some("full"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["x.hlo"]);
    }

    #[test]
    fn flag_before_flag() {
        let a = parse(&["run", "--fast", "--batch", "8"]);
        assert!(a.flag("fast"));
        assert_eq!(a.opt_usize("batch", 1), 8);
    }

    #[test]
    fn defaults() {
        let a = parse(&["serve"]);
        assert_eq!(a.opt_or("device", "cpu"), "cpu");
        assert_eq!(a.opt_f64("rate", 2.5), 2.5);
    }

    #[test]
    fn no_command() {
        let a = parse(&["--help"]);
        assert_eq!(a.command, "");
        assert!(a.flag("help"));
    }
}
