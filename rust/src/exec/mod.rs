//! Reference CPU executor over the graph IR — the numeric ground truth for
//! every optimized path in the crate (FKW sparse conv, fused elementwise
//! chains, deep-reuse GEMM), and the engine behind the use-case examples.
//!
//! Two executors:
//! * [`Executor`] — straight-line, one materialized tensor per node.
//! * [`FusedExecutor`] — consumes a [`FusionPlan`]; elementwise members of
//!   a group are applied **in place** on the producer's buffer (no
//!   allocation, no extra traversal), conv layers with a pattern
//!   assignment run through the compact [`FkwLayer`] kernel
//!   ([`FusedExecutor::attach_fkw`]), and eligible GEMM-backed ops can be
//!   routed through [`crate::deepreuse`] ([`FusedExecutor::set_reuse`]).
//!   `benches/hotpath_exec.rs` measures the gap between the two — the
//!   Rust-side stand-in for the paper's generated mobile code vs naive
//!   execution.
//!
//! The expensive per-construction analysis (group ordering, liveness,
//! buffer-pool planning, FKW encoding) lives in [`ExecState`], which
//! [`crate::api::CompiledModel`] builds once at compile time and shares
//! across runs via [`FusedExecutor::with_state`].
//!
//! Supported op subset: everything the demo CNNs / WDSR / MLP graphs use,
//! plus the transformer execution set (general-permutation `Transpose`,
//! `Embedding`/`Gather` row lookup, `Slice`, `Pad`, batched `MatMul` over
//! arbitrary leading dims) — the NLP zoo infers end-to-end. The remaining
//! estimate-only ops (`Conv3d`, `ConvTranspose2d`, `ChannelShuffle`,
//! `PostProcess`, and the RoI form of `Gather`) return an error;
//! [`eval_supported`] is the single source of truth the zoo-wide coverage
//! test checks against so new gaps fail loudly.

pub mod decode;
pub mod planner;

use std::borrow::Cow;
use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

pub use decode::{attention_specs, AttnSpec, DecodeSession, SessionSnapshot};
pub use planner::{MemoryPlan, PlanStats, Workspace, WorkspaceSpec};

use crate::deepreuse::{reuse_conv2d, reuse_conv2d_pre, reuse_gemm, ReuseConfig};
use crate::fkw::FkwLayer;
use crate::fusion::FusionPlan;
use crate::graph::{Act, Graph, NodeId, OpKind, WeightStore};
use crate::pruning::pattern::PatternAssignment;
use crate::tensor::gemm::{gemm, gemm_prepacked, GemmConfig, PackedB};
use crate::tensor::qgemm::{qgemm, qgemm_prepacked, qgemm_scratch_elems, PackedQB};
use crate::tensor::{
    conv2d_gemm_prepacked_into, conv2d_gemm_wt_into, conv2d_qgemm_prepacked_into,
    conv_weight_matrix, conv_weight_matrix_into, Tensor,
};

/// Straight-line reference executor.
pub struct Executor<'g> {
    g: &'g Graph,
    ws: &'g WeightStore,
}

impl<'g> Executor<'g> {
    pub fn new(g: &'g Graph, ws: &'g WeightStore) -> Executor<'g> {
        Executor { g, ws }
    }

    /// Evaluate the graph on `inputs` (one tensor per Input node, in id
    /// order); returns the output tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut vals: Vec<Option<Tensor>> = vec![None; self.g.nodes.len()];
        let mut next_input = 0usize;
        for n in &self.g.nodes {
            let v = match &n.op {
                OpKind::Input => {
                    let t = inputs
                        .get(next_input)
                        .ok_or_else(|| anyhow!("missing input {next_input}"))?
                        .clone();
                    if t.shape() != &n.shape[..] {
                        bail!("input {} shape {:?} != {:?}", next_input, t.shape(), n.shape);
                    }
                    next_input += 1;
                    t
                }
                OpKind::Weight => self
                    .ws
                    .get(&n.name)
                    .ok_or_else(|| anyhow!("weight '{}' missing", n.name))?
                    .clone(),
                _ => {
                    let args: Vec<&Tensor> = n
                        .inputs
                        .iter()
                        .map(|&i| vals[i].as_ref().expect("topological order"))
                        .collect();
                    eval_op(self.g, n.id, &args)?
                }
            };
            vals[n.id] = Some(v);
        }
        // Move outputs out of the value table instead of cloning them —
        // for image-sized outputs (super-resolution, segmentation) the
        // clone used to double the output footprint for nothing.
        let mut outs = Vec::with_capacity(self.g.outputs.len());
        for &o in &self.g.outputs {
            outs.push(
                vals[o]
                    .take()
                    .ok_or_else(|| anyhow!("output {o} not computed (or listed twice)"))?,
            );
        }
        Ok(outs)
    }
}

/// Evaluate a single compute op on already-evaluated inputs.
pub fn eval_op(g: &Graph, id: NodeId, args: &[&Tensor]) -> Result<Tensor> {
    let n = g.node(id);
    let out = match &n.op {
        OpKind::Conv2d { k, stride, pad, groups } => {
            let (x, w) = (args[0], args[1]);
            if *groups == 1 {
                x.conv2d(w, *stride, *pad)
            } else {
                grouped_conv2d(x, w, *k, *stride, *pad, *groups)?
            }
        }
        OpKind::Dense => {
            let (x, w) = (args[0], args[1]);
            // Collapse leading dims to rows.
            let in_f = *x.shape().last().unwrap();
            let rows = x.len() / in_f;
            let y = x.reshape(&[rows, in_f]).matmul(w);
            y.reshape(&n.shape)
        }
        OpKind::MatMul => {
            let (a, b) = (args[0], args[1]);
            batched_matmul(a, b)?
        }
        OpKind::BatchNorm => apply_bn(args[0], args[1]),
        OpKind::Bias => apply_bias(args[0], args[1], &n.shape),
        OpKind::LayerNorm => layer_norm(args[0], args[1]),
        OpKind::Activation(a) => args[0].map(act_fn(*a)),
        OpKind::Add => args[0].add(args[1]),
        OpKind::Sub => args[0].sub(args[1]),
        OpKind::Mul => args[0].mul(args[1]),
        OpKind::Div => args[0].zip(args[1], |a, b| a / b),
        OpKind::Pow { e } => {
            let e = *e as f32;
            args[0].map(move |x| x.powf(e))
        }
        // IEEE semantics: sqrt of a negative input is NaN. The old
        // `x.max(0.0).sqrt()` clamp silently laundered bad inputs into 0 —
        // the same bug class as the argmax_rows NaN panic fixed in PR 3.
        OpKind::Sqrt => args[0].map(|x| x.sqrt()),
        OpKind::Scale { mul, add } => {
            if args.len() > 1 {
                // Per-channel scale via weight.
                apply_bn(args[0], args[1])
            } else {
                let (m, a) = (*mul as f32, *add as f32);
                args[0].map(move |x| x * m + a)
            }
        }
        OpKind::CausalMask => {
            let mut out = args[0].clone();
            let l = *n.shape.last().unwrap();
            causal_mask_rows(out.data_mut(), l);
            out
        }
        OpKind::Softmax => {
            let x = args[0];
            let last = *x.shape().last().unwrap();
            // Fused masked softmax: when the scores were causally masked,
            // normalize each query row over its allowed prefix and write
            // exact zeros beyond — identical numerics to exponentiating
            // the -inf entries, without touching them.
            if matches!(g.node(n.inputs[0]).op, OpKind::CausalMask) {
                let mut out = x.clone();
                causal_softmax_rows(out.data_mut(), last);
                out
            } else {
                let rows = x.len() / last;
                x.reshape(&[rows, last]).softmax_rows().reshape(&n.shape)
            }
        }
        OpKind::MaxPool { k, stride, pad } => max_pool(args[0], *k, *stride, *pad),
        OpKind::AvgPool { k, stride, pad } => avg_pool(args[0], *k, *stride, *pad),
        OpKind::GlobalAvgPool => args[0].global_avg_pool(),
        OpKind::Reshape | OpKind::Flatten => args[0].reshape(&n.shape),
        OpKind::Transpose { perm } => transpose_nd(args[0], perm),
        OpKind::Slice { start } => slice_crop(args[0], start, &n.shape),
        OpKind::Pad { before, after } => pad_zero(args[0], before, after),
        OpKind::Embedding | OpKind::Gather => {
            if args.len() != 2 || args[1].rank() != 2 {
                bail!(
                    "executor supports only the row-lookup form of '{}' \
                     (indices + 2-D table)",
                    n.op.name()
                );
            }
            embedding_lookup(args[0], args[1])?
        }
        OpKind::Concat => concat_channels(args, &n.shape),
        OpKind::Upsample { r } => upsample(args[0], *r),
        OpKind::PixelShuffle { r } => pixel_shuffle(args[0], *r),
        OpKind::Broadcast => broadcast_to(args[0], &n.shape)?,
        other => bail!("executor does not support op '{}'", other.name()),
    };
    if out.shape() != &n.shape[..] {
        bail!(
            "op '{}' produced shape {:?}, node declares {:?}",
            n.op.name(),
            out.shape(),
            n.shape
        );
    }
    Ok(out)
}

/// Is `op` in [`eval_op`]'s executable set? This is the single source of
/// truth the zoo-wide op-coverage test (`tests/transformer.rs`) checks
/// `all_models()` against — adding an op to the zoo without a kernel (or
/// without an explicit estimate-only allowance) fails that test loudly.
///
/// `Embedding`/`Gather` are supported in their row-lookup form (indices +
/// 2-D table); the RoI/scatter `Gather` shapes some detection models use
/// are estimate-only.
// The exhaustive match (rather than a `matches!` on the unsupported set)
// is deliberate: adding an `OpKind` variant must force a decision here.
#[allow(clippy::match_like_matches_macro)]
pub fn eval_supported(op: &OpKind) -> bool {
    use OpKind::*;
    match op {
        Conv2d { .. } | Dense | MatMul | BatchNorm | Bias | LayerNorm | Activation(_) | Add
        | Sub | Mul | Div | Pow { .. } | Sqrt | Scale { .. } | CausalMask | Softmax | MaxPool { .. }
        | AvgPool { .. } | GlobalAvgPool | Reshape | Flatten | Transpose { .. } | Slice { .. }
        | Pad { .. } | Embedding | Gather | Concat | Upsample { .. } | PixelShuffle { .. }
        | Broadcast => true,
        Input | Weight => true, // sources, not evaluated through eval_op
        Conv3d { .. } | ConvTranspose2d { .. } | ChannelShuffle { .. } | PostProcess => false,
    }
}

fn act_fn(a: Act) -> impl Fn(f32) -> f32 {
    move |x| match a {
        Act::Relu => x.max(0.0),
        Act::Relu6 => x.clamp(0.0, 6.0),
        Act::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        Act::Tanh => x.tanh(),
        Act::Gelu => {
            0.5 * x * (1.0 + (0.7978845608f32 * (x + 0.044715 * x * x * x)).tanh())
        }
        Act::Swish => x / (1.0 + (-x).exp()),
        Act::HardSwish => x * (x + 3.0).clamp(0.0, 6.0) / 6.0,
        Act::LeakyRelu => {
            if x >= 0.0 {
                x
            } else {
                0.1 * x
            }
        }
        Act::Mish => x * (1.0 + x.exp()).ln().tanh(),
    }
}

fn grouped_conv2d(
    x: &Tensor,
    w: &Tensor,
    _k: usize,
    stride: usize,
    pad: usize,
    groups: usize,
) -> Result<Tensor> {
    let (n, c, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (o, ig, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    if c % groups != 0 || o % groups != 0 || ig != c / groups {
        bail!("bad grouped conv shapes");
    }
    let (cg, og) = (c / groups, o / groups);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wd + 2 * pad - kw) / stride + 1;
    let mut out = Tensor::zeros(&[n, o, oh, ow]);
    for gi in 0..groups {
        // Slice input channels and weight filters of this group.
        let mut xg = Tensor::zeros(&[n, cg, h, wd]);
        for b in 0..n {
            for ci in 0..cg {
                for y in 0..h {
                    for xx in 0..wd {
                        xg.set(&[b, ci, y, xx], x.at(&[b, gi * cg + ci, y, xx]));
                    }
                }
            }
        }
        let mut wg = Tensor::zeros(&[og, cg, kh, kw]);
        for f in 0..og {
            for ci in 0..cg {
                for y in 0..kh {
                    for xx in 0..kw {
                        wg.set(&[f, ci, y, xx], w.at(&[gi * og + f, ci, y, xx]));
                    }
                }
            }
        }
        let yg = xg.conv2d(&wg, stride, pad);
        for b in 0..n {
            for f in 0..og {
                for y in 0..oh {
                    for xx in 0..ow {
                        out.set(&[b, gi * og + f, y, xx], yg.at(&[b, f, y, xx]));
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Per-channel scale+shift (BatchNorm inference form; weight = [2, c]).
fn apply_bn(x: &Tensor, w: &Tensor) -> Tensor {
    let c = w.shape()[1];
    let mut out = x.clone();
    let per = per_channel_stride(x.shape(), c);
    let od = out.data_mut();
    for (i, v) in od.iter_mut().enumerate() {
        let ch = (i / per.0) % c;
        *v = *v * w.data()[ch] + w.data()[c + ch];
    }
    out
}

/// Per-channel bias (weight = [c]).
fn apply_bias(x: &Tensor, w: &Tensor, _shape: &[usize]) -> Tensor {
    let c = w.len();
    let mut out = x.clone();
    let per = per_channel_stride(x.shape(), c);
    let od = out.data_mut();
    for (i, v) in od.iter_mut().enumerate() {
        let ch = (i / per.0) % c;
        *v += w.data()[ch];
    }
    out
}

/// For NCHW the channel varies every h*w elements; for [.., c] layouts (2-D
/// dense outputs / sequences) it varies every element.
fn per_channel_stride(shape: &[usize], c: usize) -> (usize, ()) {
    if shape.len() >= 3 && shape[1] == c {
        (shape[2..].iter().product::<usize>(), ())
    } else {
        (1, ())
    }
}

/// LayerNorm over the last dim; weight [2, d] = (gamma, beta).
fn layer_norm(x: &Tensor, w: &Tensor) -> Tensor {
    let d = *x.shape().last().unwrap();
    let rows = x.len() / d;
    let mut out = x.clone();
    let od = out.data_mut();
    for r in 0..rows {
        let row = &mut od[r * d..(r + 1) * d];
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * w.data()[i] + w.data()[d + i];
        }
    }
    out
}

/// Shape bookkeeping shared by [`batched_matmul`] and the steady MatMul
/// kernel: `a = [..batch.., m, k]` against `b = [..batch.., k, n]` (same
/// leading dims) or a rank-2 `b = [k, n]` broadcast across every batch.
/// Returns `(batch, m, k, n, b_broadcast)`.
fn batched_matmul_dims(ashape: &[usize], bshape: &[usize]) -> Result<(usize, usize, usize, usize, bool)> {
    let (ar, br) = (ashape.len(), bshape.len());
    if ar < 2 || br < 2 {
        bail!("matmul needs rank >= 2 operands, got {ar}/{br}");
    }
    let (m, k) = (ashape[ar - 2], ashape[ar - 1]);
    let (k2, n) = (bshape[br - 2], bshape[br - 1]);
    if k != k2 {
        bail!("batched matmul mismatch: inner dims {k} vs {k2} ({ashape:?} x {bshape:?})");
    }
    let batch: usize = ashape[..ar - 2].iter().product();
    if br == 2 {
        return Ok((batch, m, k, n, true));
    }
    if ashape[..ar - 2] != bshape[..br - 2] {
        bail!("batched matmul mismatch: leading dims {ashape:?} vs {bshape:?}");
    }
    Ok((batch, m, k, n, false))
}

/// Batched matmul over flat slices, one blocked GEMM per leading-dim batch
/// (rhs broadcast collapses to a single `[batch*m, k] x [k, n]` GEMM).
/// Every per-batch multiply runs the PR-1 blocked micro-kernel on the
/// PR-3 persistent pool via [`gemm`] — operands are *sliced*, not copied
/// (the old rank-3 path rebuilt both operands with `to_vec` per batch).
fn batched_matmul_into(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    b_broadcast: bool,
    cfg: &GemmConfig,
    out: &mut [f32],
) {
    if b_broadcast {
        gemm(batch * m, k, n, a, b, &mut out[..batch * m * n], cfg);
        return;
    }
    for bi in 0..batch {
        gemm(
            m,
            k,
            n,
            &a[bi * m * k..(bi + 1) * m * k],
            &b[bi * k * n..(bi + 1) * k * n],
            &mut out[bi * m * n..(bi + 1) * m * n],
            cfg,
        );
    }
}

/// Int8 twin of [`batched_matmul_into`]: both operands are activations, so
/// each per-batch multiply runs the dynamically-quantizing
/// [`crate::tensor::qgemm::qgemm`] (per-tensor scales derived per batch
/// slice). This is the quantized-attention contraction path — int8 QK^T
/// and int8 AV around the unchanged f32 masked softmax. Like the f32
/// batched matmul, it is not part of the zero-allocation guarantee (the
/// int8 kernel packs into its own buffers here).
#[allow(clippy::too_many_arguments)]
fn batched_qmatmul_into(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    b_broadcast: bool,
    cfg: &GemmConfig,
    out: &mut [f32],
) {
    if b_broadcast {
        qgemm(batch * m, k, n, a, b, &mut out[..batch * m * n], cfg);
        return;
    }
    for bi in 0..batch {
        qgemm(
            m,
            k,
            n,
            &a[bi * m * k..(bi + 1) * m * k],
            &b[bi * k * n..(bi + 1) * k * n],
            &mut out[bi * m * n..(bi + 1) * m * n],
            cfg,
        );
    }
}

/// Batched matmul over arbitrary leading dims: `[..., m, k] x [..., k, n]`
/// (or a 2-D rhs broadcast across every batch) — rank-4 attention shapes
/// (`[n, heads, L, d_h]`) included.
///
/// Runs with `GemmConfig::default()`: [`eval_op`] is the session-agnostic
/// oracle and has no channel to a compiled session's config — the same
/// convention as the Dense arm's `Tensor::matmul`. Session knobs
/// (`threads: 1`, blocking) apply on the steady engine, which calls
/// [`batched_matmul_into`] with its `ExecState` config.
fn batched_matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    let (batch, m, k, n, b_broadcast) = batched_matmul_dims(a.shape(), b.shape())?;
    let mut shape = a.shape()[..a.rank() - 2].to_vec();
    shape.push(m);
    shape.push(n);
    let mut out = Tensor::zeros(&shape);
    batched_matmul_into(
        a.data(),
        b.data(),
        batch,
        m,
        k,
        n,
        b_broadcast,
        &GemmConfig::default(),
        out.data_mut(),
    );
    Ok(out)
}

/// General N-d axis permutation (`out.shape[i] = in.shape[perm[i]]`).
fn transpose_nd(x: &Tensor, perm: &[usize]) -> Tensor {
    let shape: Vec<usize> = perm.iter().map(|&p| x.shape()[p]).collect();
    let mut out = Tensor::zeros(&shape);
    transpose_into(x.data(), x.shape(), perm, out.data_mut());
    out
}

/// [`transpose_nd`] into a caller buffer — the steady-state form (pure
/// index copy, no scratch).
fn transpose_into(x: &[f32], xshape: &[usize], perm: &[usize], out: &mut [f32]) {
    let rank = xshape.len();
    debug_assert_eq!(perm.len(), rank);
    // Input strides (row-major), permuted to output-axis order: walking
    // the output linearly advances the input index by in_stride[perm[d]]
    // per step of output dim d.
    let mut in_stride = vec![0usize; rank];
    let mut s = 1usize;
    for d in (0..rank).rev() {
        in_stride[d] = s;
        s *= xshape[d];
    }
    let out_shape: Vec<usize> = perm.iter().map(|&p| xshape[p]).collect();
    let stride: Vec<usize> = perm.iter().map(|&p| in_stride[p]).collect();
    debug_assert!(out.len() >= x.len());
    let mut idx = vec![0usize; rank];
    let mut src = 0usize;
    for o in out.iter_mut().take(x.len()) {
        *o = x[src];
        // Odometer increment over the output index space.
        for d in (0..rank).rev() {
            idx[d] += 1;
            src += stride[d];
            if idx[d] < out_shape[d] {
                break;
            }
            src -= stride[d] * out_shape[d];
            idx[d] = 0;
        }
    }
}

/// Contiguous crop: take `out_shape[d]` elements starting at `start[d]`.
fn slice_crop(x: &Tensor, start: &[usize], out_shape: &[usize]) -> Tensor {
    let mut out = Tensor::zeros(out_shape);
    slice_into(x.data(), x.shape(), start, out_shape, out.data_mut());
    out
}

fn slice_into(x: &[f32], xshape: &[usize], start: &[usize], out_shape: &[usize], out: &mut [f32]) {
    let rank = xshape.len();
    let mut in_stride = vec![0usize; rank];
    let mut s = 1usize;
    for d in (0..rank).rev() {
        in_stride[d] = s;
        s *= xshape[d];
    }
    let base: usize = start.iter().zip(&in_stride).map(|(&a, &b)| a * b).sum();
    // Copy row-by-row over the innermost dim (contiguous in both layouts).
    let inner = out_shape[rank - 1];
    let rows: usize = out_shape[..rank - 1].iter().product();
    let mut idx = vec![0usize; rank.max(1) - 1];
    for r in 0..rows {
        let mut src = base;
        for (d, &i) in idx.iter().enumerate() {
            src += i * in_stride[d];
        }
        out[r * inner..(r + 1) * inner].copy_from_slice(&x[src..src + inner]);
        for d in (0..rank - 1).rev() {
            idx[d] += 1;
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

/// Zero padding: `before[d]`/`after[d]` zeros around each dim.
fn pad_zero(x: &Tensor, before: &[usize], after: &[usize]) -> Tensor {
    let out_shape: Vec<usize> = x
        .shape()
        .iter()
        .zip(before)
        .zip(after)
        .map(|((&s, &b), &a)| s + b + a)
        .collect();
    let mut out = Tensor::zeros(&out_shape);
    pad_into(x.data(), x.shape(), before, &out_shape, out.data_mut());
    out
}

/// Scatter `x` into the zero-filled `out` at offset `before` (out is
/// cleared here, so the steady engine can reuse a dirty arena buffer).
fn pad_into(x: &[f32], xshape: &[usize], before: &[usize], out_shape: &[usize], out: &mut [f32]) {
    out.fill(0.0);
    let rank = xshape.len();
    let mut out_stride = vec![0usize; rank];
    let mut s = 1usize;
    for d in (0..rank).rev() {
        out_stride[d] = s;
        s *= out_shape[d];
    }
    let base: usize = before.iter().zip(&out_stride).map(|(&a, &b)| a * b).sum();
    let inner = xshape[rank - 1];
    let rows: usize = xshape[..rank - 1].iter().product();
    let mut idx = vec![0usize; rank.max(1) - 1];
    for r in 0..rows {
        let mut dst = base;
        for (d, &i) in idx.iter().enumerate() {
            dst += i * out_stride[d];
        }
        out[dst..dst + inner].copy_from_slice(&x[r * inner..(r + 1) * inner]);
        for d in (0..rank - 1).rev() {
            idx[d] += 1;
            if idx[d] < xshape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

/// Row lookup: `ids` (any shape, f32-encoded integer ids) against a
/// `[vocab, d]` table → `ids.shape + [d]`. Out-of-range or non-integral
/// ids are a loud error, not a clamp.
fn embedding_lookup(ids: &Tensor, table: &Tensor) -> Result<Tensor> {
    let (vocab, d) = (table.shape()[0], table.shape()[1]);
    let mut shape = ids.shape().to_vec();
    shape.push(d);
    let mut out = Tensor::zeros(&shape);
    embedding_into(ids.data(), table.data(), vocab, d, out.data_mut())?;
    Ok(out)
}

fn embedding_into(ids: &[f32], table: &[f32], vocab: usize, d: usize, out: &mut [f32]) -> Result<()> {
    debug_assert!(out.len() >= ids.len() * d);
    for (i, &idf) in ids.iter().enumerate() {
        let id = idf as isize;
        if id < 0 || id as usize >= vocab || idf.fract() != 0.0 {
            bail!("embedding id {idf} out of range for vocab {vocab}");
        }
        let row = id as usize;
        out[i * d..(i + 1) * d].copy_from_slice(&table[row * d..(row + 1) * d]);
    }
    Ok(())
}

/// k×k/stride max pool with symmetric zero padding over NCHW (padding
/// contributes no candidates — max over in-bounds taps only).
fn max_pool(x: &Tensor, k: usize, stride: usize, pad: usize) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    max_pool_into(x.data(), n, c, h, w, k, stride, pad, out.data_mut());
    out
}

/// k×k/stride average pool with symmetric zero padding; windowed output
/// shape `(h + 2*pad − k)/stride + 1` — the old `h/stride` shape ignored
/// the kernel size and was wrong for every k ≠ stride.
fn avg_pool(x: &Tensor, k: usize, stride: usize, pad: usize) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    avg_pool_into(x.data(), n, c, h, w, k, stride, pad, out.data_mut());
    out
}

fn concat_channels(args: &[&Tensor], shape: &[usize]) -> Tensor {
    let mut out = Tensor::zeros(shape);
    let (n, h, w) = (shape[0], shape[2], shape[3]);
    let mut c0 = 0usize;
    for a in args {
        let ca = a.shape()[1];
        for b in 0..n {
            for ci in 0..ca {
                for y in 0..h {
                    for xx in 0..w {
                        out.set(&[b, c0 + ci, y, xx], a.at(&[b, ci, y, xx]));
                    }
                }
            }
        }
        c0 += ca;
    }
    out
}

fn upsample(x: &Tensor, r: usize) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = Tensor::zeros(&[n, c, h * r, w * r]);
    for b in 0..n {
        for ci in 0..c {
            for y in 0..h * r {
                for xx in 0..w * r {
                    out.set(&[b, ci, y, xx], x.at(&[b, ci, y / r, xx / r]));
                }
            }
        }
    }
    out
}

fn pixel_shuffle(x: &Tensor, r: usize) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oc = c / (r * r);
    let mut out = Tensor::zeros(&[n, oc, h * r, w * r]);
    for b in 0..n {
        for co in 0..oc {
            for y in 0..h {
                for xx in 0..w {
                    for dy in 0..r {
                        for dx in 0..r {
                            let ci = co * r * r + dy * r + dx;
                            out.set(&[b, co, y * r + dy, xx * r + dx], x.at(&[b, ci, y, xx]));
                        }
                    }
                }
            }
        }
    }
    out
}

fn broadcast_to(x: &Tensor, shape: &[usize]) -> Result<Tensor> {
    // Supported: [c] or [1] -> [n, c, h, w] (channel gates) and
    // [a, b] -> [n, a, b].
    if x.len() == 1 {
        return Ok(Tensor::full(shape, x.data()[0]));
    }
    if x.rank() == 2 && shape.len() == 3 && x.shape() == &shape[1..] {
        let mut out = Tensor::zeros(shape);
        let per = x.len();
        for b in 0..shape[0] {
            out.data_mut()[b * per..(b + 1) * per].copy_from_slice(x.data());
        }
        return Ok(out);
    }
    if x.rank() == 2 && shape.len() == 4 && x.shape()[1] == shape[1] {
        // [n, c] gate -> [n, c, h, w]
        let (n, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
        let mut out = Tensor::zeros(shape);
        for b in 0..n {
            for ci in 0..c {
                let v = x.at(&[b, ci]);
                for y in 0..h {
                    for xx in 0..w {
                        out.set(&[b, ci, y, xx], v);
                    }
                }
            }
        }
        return Ok(out);
    }
    bail!("unsupported broadcast {:?} -> {:?}", x.shape(), shape)
}

/// Precomputed execution state for one graph under one fusion plan: the
/// flattened group order, the materialization mask, the buffer-pool memory
/// plan, FKW-encoded conv layers, and the optional deep-reuse routing
/// config.
///
/// Building this is the expensive part of constructing a [`FusedExecutor`]
/// (a liveness pass over the whole graph). The [`crate::api`] compiler
/// builds it **once** at compile time and reuses it across every
/// `CompiledModel::infer` call via [`FusedExecutor::with_state`].
#[derive(Debug, Clone)]
pub struct ExecState {
    /// Indices into `plan.groups` in execution order (sorted by first
    /// member; the plan preserves topological order within and across
    /// groups by construction).
    group_order: Vec<usize>,
    /// Which values materialize into pooled slots: group tails and members
    /// whose value escapes their group. Derived once from users() here
    /// (§Perf iteration 1: users() used to be recomputed per node, costing
    /// O(V·E) on deep graphs).
    materialize: Vec<bool>,
    /// Buffer pool plan over the flattened group order (§Perf iteration 3:
    /// computed once here, not per run).
    mplan: MemoryPlan,
    /// conv node id -> FKW-encoded layer.
    fkw: BTreeMap<NodeId, FkwLayer>,
    /// When set, eligible GEMM-backed ops — groups=1 `Conv2d` of any
    /// kernel size (via im2col) and `Dense` — without an FKW kernel route
    /// through [`crate::deepreuse`].
    reuse: Option<ReuseConfig>,
    /// Constant GEMM operands pre-packed at compile time
    /// ([`ExecState::prepack`]).
    packed: PackedWeights,
    /// Contraction nodes the quant plan selected for int8 execution
    /// ([`ExecState::set_quant`]): Dense and groups=1 conv weights pack to
    /// [`PackedQB`] at prepack time; `MatMul` members route through the
    /// dynamically-quantizing kernel at run time.
    quant: BTreeSet<NodeId>,
    /// Blocking/thread config of the steady-state engine (packs and runs
    /// must agree, so it lives here).
    gemm_cfg: GemmConfig,
    /// Workspace arena sizing from the extended liveness pass.
    wspec: WorkspaceSpec,
    /// node id -> Input position, for allocation-free source lookup in
    /// the steady engine (usize::MAX for non-Input nodes).
    input_pos: Vec<usize>,
}

/// Constant GEMM operands packed once at `Compiler::compile` time and
/// carried by [`ExecState`]: Dense weights and transposed conv weight
/// matrices in the panel layout [`gemm_prepacked`] consumes, plus
/// pre-transposed weight matrices for deep-reuse-routed convs. Steady-state
/// inference never re-packs or re-transposes a weight.
#[derive(Debug, Clone, Default)]
pub struct PackedWeights {
    /// Dense node id -> packed `[in_f, out_f]` operand.
    dense: BTreeMap<NodeId, PackedB>,
    /// groups=1 conv node id -> packed transposed `[i*kh*kw, o]` operand.
    conv: BTreeMap<NodeId, PackedB>,
    /// Deep-reuse-routed conv node id -> transposed `[i*kh*kw, o]` weight
    /// matrix (reuse clusters per call, so only the transpose is cached).
    reuse_wt: BTreeMap<NodeId, Tensor>,
    /// Quantized Dense node id -> int8-packed `[in_f, out_f]` operand with
    /// per-output-channel dequant scales. A node is in `qdense` *or*
    /// `dense`, never both — the quant plan decides at prepack time.
    qdense: BTreeMap<NodeId, PackedQB>,
    /// Quantized groups=1 conv node id -> int8-packed transposed
    /// `[i*kh*kw, o]` filter matrix (per-output-channel scales).
    qconv: BTreeMap<NodeId, PackedQB>,
}

impl PackedWeights {
    /// Number of pre-packed operands.
    pub fn len(&self) -> usize {
        self.dense.len()
            + self.conv.len()
            + self.reuse_wt.len()
            + self.qdense.len()
            + self.qconv.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of operands packed in int8 (the quantized subset of `len`).
    pub fn int8_len(&self) -> usize {
        self.qdense.len() + self.qconv.len()
    }

    /// Resident bytes of the side table.
    pub fn bytes(&self) -> u64 {
        self.dense.values().map(|p| p.bytes()).sum::<u64>()
            + self.conv.values().map(|p| p.bytes()).sum::<u64>()
            + self.reuse_wt.values().map(|t| t.len() as u64 * 4).sum::<u64>()
            + self.qdense.values().map(|p| p.bytes()).sum::<u64>()
            + self.qconv.values().map(|p| p.bytes()).sum::<u64>()
    }

    /// Per-output-channel dequant scales of a quantized node's packed
    /// weight (Dense or conv), if that node was int8-packed — the bitwise
    /// source of truth the scale-agreement test pins against
    /// [`crate::analyze::quant::QuantPlan`].
    pub fn int8_scales(&self, id: NodeId) -> Option<&[f32]> {
        self.qdense
            .get(&id)
            .or_else(|| self.qconv.get(&id))
            .map(|p| p.col_scales.as_slice())
    }
}

impl ExecState {
    /// Run the ordering + liveness analysis for `g` under `plan`.
    pub fn new(g: &Graph, plan: &FusionPlan) -> ExecState {
        let users = g.users();
        let mut group_order: Vec<usize> = (0..plan.groups.len()).collect();
        group_order.sort_by_key(|&gi| plan.groups[gi].nodes[0]);
        let order: Vec<NodeId> = group_order
            .iter()
            .flat_map(|&gi| plan.groups[gi].nodes.iter().copied())
            .collect();
        let mut materialize = vec![false; g.nodes.len()];
        for &gi in &group_order {
            let gr = &plan.groups[gi];
            for &id in &gr.nodes {
                let escapes = users[id].iter().any(|&u| !gr.nodes.contains(&u))
                    || g.outputs.contains(&id);
                if id == *gr.nodes.last().unwrap() || escapes {
                    materialize[id] = true;
                }
            }
        }
        let mplan = MemoryPlan::new(g, &order, &materialize);
        let wspec = WorkspaceSpec::for_graph(g, &mplan, &materialize);
        let mut input_pos = vec![usize::MAX; g.nodes.len()];
        let mut next_input = 0usize;
        for n in &g.nodes {
            if matches!(n.op, OpKind::Input) {
                input_pos[n.id] = next_input;
                next_input += 1;
            }
        }
        ExecState {
            group_order,
            materialize,
            mplan,
            fkw: BTreeMap::new(),
            reuse: None,
            packed: PackedWeights::default(),
            quant: BTreeSet::new(),
            gemm_cfg: GemmConfig::default(),
            wspec,
            input_pos,
        }
    }

    /// Register a pattern assignment for a conv node: it will execute via
    /// the compact FKW kernel.
    pub fn attach_fkw(
        &mut self,
        g: &Graph,
        ws: &WeightStore,
        node: NodeId,
        asg: &PatternAssignment,
    ) -> Result<()> {
        let n = g.node(node);
        let OpKind::Conv2d { stride, pad, groups: 1, k: 3 } = n.op else {
            bail!("FKW applies to 3x3 groups=1 conv nodes");
        };
        let wname = &g
            .node(
                *n.inputs
                    .iter()
                    .find(|&&i| matches!(g.node(i).op, OpKind::Weight))
                    .ok_or_else(|| anyhow!("conv without weight"))?,
            )
            .name;
        let w = ws.get(wname).ok_or_else(|| anyhow!("weight missing"))?;
        self.fkw.insert(node, FkwLayer::encode(w, asg, stride, pad, true));
        Ok(())
    }

    /// Route eligible ops through deep reuse (`None` disables).
    pub fn set_reuse(&mut self, cfg: Option<ReuseConfig>) {
        self.reuse = cfg;
    }

    /// Select the contraction nodes that execute in int8 (the compiler's
    /// quant plan). Must be called **before** [`ExecState::prepack`]: the
    /// set decides which weights pack to [`PackedQB`] instead of the f32
    /// panel layout. FKW- and reuse-routed nodes are skipped at prepack
    /// time regardless of membership.
    pub fn set_quant(&mut self, nodes: BTreeSet<NodeId>) {
        self.quant = nodes;
    }

    /// The int8-selected node set (empty when quantization is off).
    pub fn quant_nodes(&self) -> &BTreeSet<NodeId> {
        &self.quant
    }

    /// Number of conv nodes with an attached FKW kernel.
    pub fn fkw_count(&self) -> usize {
        self.fkw.len()
    }

    /// Whether node `id` executes through an attached FKW kernel (such
    /// nodes never pack — f32 or int8 — and the precision report blames
    /// the routing, not the quant plan).
    pub fn has_fkw(&self, id: NodeId) -> bool {
        self.fkw.contains_key(&id)
    }

    /// The memory planner's pool statistics.
    pub fn plan_stats(&self) -> &PlanStats {
        &self.mplan.stats
    }

    /// The flattened execution order (groups in execution order, members
    /// in group order) — exactly what the memory plan was computed
    /// against. [`crate::verify::check_plan`] replays liveness over it.
    pub fn execution_order(&self, plan: &FusionPlan) -> Vec<NodeId> {
        self.group_order
            .iter()
            .flat_map(|&gi| plan.groups[gi].nodes.iter().copied())
            .collect()
    }

    /// Which values materialize into pooled slots (group tails and
    /// members whose value escapes their group).
    pub fn materialize_mask(&self) -> &[bool] {
        &self.materialize
    }

    /// The buffer-pool memory plan over the flattened order.
    pub fn memory_plan(&self) -> &MemoryPlan {
        &self.mplan
    }

    /// Set the GEMM blocking/thread config of the steady-state engine
    /// (pack-time and run-time blocking must agree, so change it before
    /// [`ExecState::prepack`]).
    pub fn set_gemm_config(&mut self, cfg: GemmConfig) {
        self.gemm_cfg = cfg;
    }

    pub fn gemm_config(&self) -> &GemmConfig {
        &self.gemm_cfg
    }

    /// Pre-pack every constant GEMM operand: Dense weights and transposed
    /// conv weight matrices into [`PackedB`] panels, pre-transposed weight
    /// matrices for deep-reuse-routed convs. Call **after** FKW attachment
    /// and reuse routing are final — FKW convs keep their compact kernels
    /// and are skipped here. Returns the number of operands packed.
    pub fn prepack(&mut self, g: &Graph, ws: &WeightStore) -> Result<usize> {
        self.packed = PackedWeights::default();
        for n in &g.nodes {
            let wid = match n.op {
                OpKind::Dense | OpKind::Conv2d { groups: 1, .. } => n
                    .inputs
                    .iter()
                    .copied()
                    .find(|&i| matches!(g.node(i).op, OpKind::Weight)),
                _ => None,
            };
            let Some(wid) = wid else { continue };
            let w = ws
                .get(&g.node(wid).name)
                .ok_or_else(|| anyhow!("weight '{}' missing", g.node(wid).name))?;
            match n.op {
                OpKind::Dense => {
                    if self.reuse.is_some() {
                        // Deep reuse multiplies centroids against the raw
                        // [in, out] weight — nothing to pre-pack.
                        continue;
                    }
                    if self.quant.contains(&n.id) {
                        // Int8: quantize per output channel and pack once;
                        // the f32 panel table is not built for this node.
                        self.packed.qdense.insert(n.id, PackedQB::from_weight(w, &self.gemm_cfg)?);
                        continue;
                    }
                    let (in_f, out_f) = (w.shape()[0], w.shape()[1]);
                    self.packed
                        .dense
                        .insert(n.id, PackedB::pack(in_f, out_f, w.data(), &self.gemm_cfg));
                }
                OpKind::Conv2d { groups: 1, .. } => {
                    if self.fkw.contains_key(&n.id) {
                        continue;
                    }
                    if self.reuse.is_none() && self.quant.contains(&n.id) {
                        self.packed.qconv.insert(n.id, PackedQB::from_weight(w, &self.gemm_cfg)?);
                        continue;
                    }
                    let wt = conv_weight_matrix(w); // [i*kh*kw, o]
                    if self.reuse.is_some() {
                        self.packed.reuse_wt.insert(n.id, wt);
                    } else {
                        let (cols, o) = (wt.shape()[0], wt.shape()[1]);
                        self.packed
                            .conv
                            .insert(n.id, PackedB::pack(cols, o, wt.data(), &self.gemm_cfg));
                    }
                }
                _ => {}
            }
        }
        Ok(self.packed.len())
    }

    /// Pre-packed operand count and resident bytes.
    pub fn packed_stats(&self) -> (usize, u64) {
        (self.packed.len(), self.packed.bytes())
    }

    /// Per-output-channel dequant scales of node `id`'s int8-packed weight
    /// (Dense or conv), when the quant plan selected it and prepack built
    /// the [`PackedQB`] table. `None` for f32-packed, FKW-routed and
    /// dynamically-quantized (`MatMul`) nodes.
    pub fn int8_scales(&self, id: NodeId) -> Option<&[f32]> {
        self.packed.int8_scales(id)
    }

    /// Node ids whose weights were actually int8-packed at prepack time —
    /// the truthful subset of [`ExecState::quant_nodes`] (FKW- and
    /// reuse-routed members are skipped at prepack). Sorted by id.
    pub fn int8_packed_nodes(&self) -> Vec<NodeId> {
        let mut ids: Vec<NodeId> = self
            .packed
            .qdense
            .keys()
            .chain(self.packed.qconv.keys())
            .copied()
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Number of int8-packed operands (see [`PackedWeights::int8_len`]).
    pub fn int8_packed_len(&self) -> usize {
        self.packed.int8_len()
    }

    /// The workspace arena sizing of this state.
    pub fn workspace_spec(&self) -> &WorkspaceSpec {
        &self.wspec
    }

    /// Allocate a fresh workspace arena sized for this state — done once
    /// at compile time; every steady-state `infer` borrows it mutably.
    pub fn workspace(&self) -> Workspace {
        Workspace::new(&self.wspec, &self.gemm_cfg)
    }

    /// Flat view of a planned value inside `ws` (used by the API layer to
    /// read outputs after a `run_steady`).
    pub fn planned_slice<'w>(&self, ws: &'w Workspace, id: NodeId, elems: usize) -> Option<&'w [f32]> {
        self.mplan.slot_of[id].map(|s| &ws.slots[s][..elems])
    }

    /// Ordinal of an `Input` node among the graph's inputs (`None` for
    /// any other node) — the single source of the "input position =
    /// count of Input nodes before it" rule.
    pub fn input_position(&self, id: NodeId) -> Option<usize> {
        match self.input_pos.get(id) {
            Some(&p) if p != usize::MAX => Some(p),
            _ => None,
        }
    }
}

/// Optimized executor: in-place elementwise within fused groups + FKW
/// sparse conv kernels for layers with a pattern assignment + optional
/// deep-reuse GEMM routing.
pub struct FusedExecutor<'g> {
    g: &'g Graph,
    ws: &'g WeightStore,
    plan: &'g FusionPlan,
    state: Cow<'g, ExecState>,
}

impl<'g> FusedExecutor<'g> {
    /// Build an executor, computing a fresh [`ExecState`].
    pub fn new(g: &'g Graph, ws: &'g WeightStore, plan: &'g FusionPlan) -> FusedExecutor<'g> {
        FusedExecutor { g, ws, plan, state: Cow::Owned(ExecState::new(g, plan)) }
    }

    /// Build an executor over a prebuilt state — no per-construction
    /// liveness analysis. This is the `xgen::api::CompiledModel` hot path:
    /// compile once, infer many times.
    pub fn with_state(
        g: &'g Graph,
        ws: &'g WeightStore,
        plan: &'g FusionPlan,
        state: &'g ExecState,
    ) -> FusedExecutor<'g> {
        FusedExecutor { g, ws, plan, state: Cow::Borrowed(state) }
    }

    /// Register a pattern assignment for a conv node (attach-style, so
    /// conditional attachment composes without rebinding `self`).
    pub fn attach_fkw(&mut self, node: NodeId, asg: &PatternAssignment) -> Result<()> {
        let (g, ws) = (self.g, self.ws);
        self.state.to_mut().attach_fkw(g, ws, node, asg)
    }

    /// Consuming form of [`FusedExecutor::attach_fkw`], kept for one
    /// release for source compatibility.
    #[deprecated(note = "use attach_fkw (&mut self) instead")]
    pub fn with_fkw(mut self, node: NodeId, asg: &PatternAssignment) -> Result<Self> {
        self.attach_fkw(node, asg)?;
        Ok(self)
    }

    /// Route eligible GEMM-backed ops through deep reuse.
    pub fn set_reuse(&mut self, cfg: Option<ReuseConfig>) {
        self.state.to_mut().set_reuse(cfg);
    }

    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.run_with_stats(inputs).map(|(y, _)| y)
    }

    /// Run and also return the memory planner's pool statistics —
    /// `benches/gemm_blocked.rs` and the e2e tests report `slots` vs
    /// `planned_values` as the peak-live-allocation reduction.
    pub fn run_with_stats(&self, inputs: &[Tensor]) -> Result<(Vec<Tensor>, PlanStats)> {
        // Sources are *referenced* from the caller's inputs and the weight
        // store — the per-run clone of every weight tensor is gone.
        let mut src: Vec<Option<&Tensor>> = vec![None; self.g.nodes.len()];
        let mut next_input = 0usize;
        for n in &self.g.nodes {
            match &n.op {
                OpKind::Input => {
                    let t = inputs
                        .get(next_input)
                        .ok_or_else(|| anyhow!("missing input {next_input}"))?;
                    src[n.id] = Some(t);
                    next_input += 1;
                }
                OpKind::Weight => {
                    src[n.id] = Some(
                        self.ws
                            .get(&n.name)
                            .ok_or_else(|| anyhow!("weight '{}' missing", n.name))?,
                    );
                }
                _ => {}
            }
        }
        // Materialized values live in a planned pool of reusable slots
        // instead of one entry per node; a value's buffer is dropped as
        // soon as its last consumer has run.
        let state: &ExecState = &self.state;
        let mut slots: Vec<Option<Tensor>> = (0..state.mplan.num_slots).map(|_| None).collect();

        let mut p = 0usize; // position in the flattened group order
        for &gi in &state.group_order {
            let gr = &self.plan.groups[gi];
            // Fused evaluation: walk members; elementwise unary members
            // mutate the running buffer in place.
            let mut buf: Option<Tensor> = None;
            let mut prev_id: Option<NodeId> = None;
            for &id in &gr.nodes {
                let n = self.g.node(id);
                let in_place = buf.is_some()
                    && n.inputs.len() == 1
                    && prev_id == Some(n.inputs[0])
                    && matches!(
                        n.op,
                        OpKind::Activation(_)
                            | OpKind::Scale { .. }
                            | OpKind::Pow { .. }
                            | OpKind::Sqrt
                    );
                let out = if in_place {
                    let mut t = buf.take().unwrap();
                    apply_unary_inplace(&n.op, &mut t);
                    t
                } else if let Some(fkw) = state.fkw.get(&id) {
                    let xid = n
                        .inputs
                        .iter()
                        .copied()
                        .find(|&i| !matches!(self.g.node(i).op, OpKind::Weight))
                        .ok_or_else(|| anyhow!("conv without data input"))?;
                    let x = planned_value(&state.mplan, &slots, &src, xid)
                        .ok_or_else(|| anyhow!("missing conv input {xid}"))?;
                    // Honor the session's thread config (`threads: 1`
                    // must disable the pool on this engine too, not just
                    // on the steady path).
                    let xs = x.shape();
                    let mut out = Tensor::zeros(&n.shape);
                    fkw.conv2d_into(
                        x.data(),
                        xs[0],
                        xs[2],
                        xs[3],
                        state.gemm_cfg.resolved_threads(),
                        out.data_mut(),
                    );
                    out
                } else {
                    let prev = buf.take();
                    let mut args: Vec<&Tensor> = Vec::with_capacity(n.inputs.len());
                    for &i in &n.inputs {
                        // The running buffer stands in only for the
                        // *immediately preceding* member; anything else
                        // must be materialized, and a miss is a loud
                        // error, not a silent wrong-tensor substitution.
                        let v = planned_value(&state.mplan, &slots, &src, i)
                            .or(if prev_id == Some(i) { prev.as_ref() } else { None })
                            .ok_or_else(|| {
                                anyhow!(
                                    "input {i} of node {id} not materialized — \
                                     fusion order is not topological"
                                )
                            })?;
                        args.push(v);
                    }
                    // Deep-reuse routing: eligible GEMM-backed ops go
                    // through the LSH-clustered engine when enabled.
                    match (&n.op, state.reuse) {
                        (OpKind::Conv2d { stride, pad, groups: 1, .. }, Some(cfg)) => {
                            reuse_conv2d(args[0], args[1], *stride, *pad, &cfg).0
                        }
                        (OpKind::Dense, Some(cfg)) => {
                            let in_f = *args[0].shape().last().unwrap();
                            let rows = args[0].len() / in_f;
                            let xm = args[0].reshape(&[rows, in_f]);
                            reuse_gemm(&xm, args[1], &cfg).0.reshape(&n.shape)
                        }
                        // Int8 plan members (this engine allocates its
                        // buffers per call; the arena-backed steady engine
                        // is the zero-allocation path).
                        (OpKind::Dense, None) if state.packed.qdense.contains_key(&id) => {
                            let pqb = &state.packed.qdense[&id];
                            let in_f = *args[0].shape().last().unwrap();
                            let rows = args[0].len() / in_f;
                            let mut y = Tensor::zeros(&n.shape);
                            let mut qs = vec![
                                0i8;
                                qgemm_scratch_elems(&state.gemm_cfg)
                                    * state.gemm_cfg.resolved_threads()
                            ];
                            qgemm_prepacked(
                                rows,
                                args[0].data(),
                                pqb,
                                y.data_mut(),
                                &state.gemm_cfg,
                                &mut qs,
                            );
                            y
                        }
                        (OpKind::Conv2d { stride, pad, groups: 1, .. }, None)
                            if state.packed.qconv.contains_key(&id) =>
                        {
                            let pqb = &state.packed.qconv[&id];
                            let xs = args[0].shape();
                            let (nb, c, h, w) = (xs[0], xs[1], xs[2], xs[3]);
                            let wsh = args[1].shape(); // [o, i, kh, kw]
                            let (kh, kw) = (wsh[2], wsh[3]);
                            let oh = (h + 2 * pad - kh) / stride + 1;
                            let ow = (w + 2 * pad - kw) / stride + 1;
                            let rows = nb * oh * ow;
                            let cols = c * kh * kw;
                            let mut patches = vec![0.0f32; rows * cols];
                            let mut gout = vec![0.0f32; rows * pqb.n];
                            let mut qs = vec![
                                0i8;
                                qgemm_scratch_elems(&state.gemm_cfg)
                                    * state.gemm_cfg.resolved_threads()
                            ];
                            let mut y = Tensor::zeros(&n.shape);
                            conv2d_qgemm_prepacked_into(
                                args[0].data(),
                                nb,
                                c,
                                h,
                                w,
                                pqb,
                                kh,
                                kw,
                                *stride,
                                *pad,
                                &state.gemm_cfg,
                                &mut patches,
                                &mut gout,
                                &mut qs,
                                y.data_mut(),
                            );
                            y
                        }
                        (OpKind::MatMul, _) if state.quant.contains(&id) => {
                            let (batch, m, k, nn, bb) =
                                batched_matmul_dims(args[0].shape(), args[1].shape())?;
                            let mut y = Tensor::zeros(&n.shape);
                            batched_qmatmul_into(
                                args[0].data(),
                                args[1].data(),
                                batch,
                                m,
                                k,
                                nn,
                                bb,
                                &state.gemm_cfg,
                                y.data_mut(),
                            );
                            y
                        }
                        _ => eval_op(self.g, id, &args)?,
                    }
                };
                // Tail of group keeps the buffer; intermediates whose value
                // escapes the group are materialized into their slot.
                buf = Some(out);
                if id == *gr.nodes.last().unwrap() {
                    // Tail: the buffer's last stop — move, don't clone
                    // (§Perf iteration 2: the clone here copied every
                    // group-boundary tensor twice).
                    let slot = state.mplan.slot_of[id].expect("tail has a slot");
                    slots[slot] = buf.take();
                } else if state.materialize[id] {
                    let slot = state.mplan.slot_of[id].expect("escaping value has a slot");
                    slots[slot] = buf.clone();
                }
                // Recycle buffers whose last consumer just ran.
                for &d in &state.mplan.expire[p] {
                    if let Some(s) = state.mplan.slot_of[d] {
                        slots[s] = None;
                    }
                }
                p += 1;
                prev_id = Some(id);
            }
        }
        let mut outs = Vec::with_capacity(self.g.outputs.len());
        for &o in &self.g.outputs {
            let t = if let Some(t) = src[o] {
                t.clone()
            } else {
                let s = state.mplan.slot_of[o].ok_or_else(|| anyhow!("output {o} not planned"))?;
                slots[s]
                    .take()
                    .ok_or_else(|| anyhow!("output {o} not computed (or listed twice)"))?
            };
            outs.push(t);
        }
        Ok((outs, state.mplan.stats.clone()))
    }

    /// Steady-state execution: every value lands in the pre-sized
    /// [`Workspace`] arena — planned slots for materialized values,
    /// ping-pong buffers for intra-group intermediates, dedicated scratch
    /// for im2col/GEMM staging. With pre-packed weights attached
    /// ([`ExecState::prepack`]) the hot loop performs **no heap
    /// allocation and spawns no threads**: GEMM row bands and FKW filter
    /// bands run on the persistent pool. Outputs stay in the arena; read
    /// them through [`ExecState::planned_slice`].
    ///
    /// The transformer set executes natively in-arena too: batched
    /// `MatMul` (per-batch GEMMs on the blocked micro-kernel),
    /// general-permutation `Transpose`, `Embedding`/`Gather` row lookup,
    /// `Slice` and `Pad` — so the attention path (QK^T → scale → softmax
    /// → AV) stays inside the workspace. Ops outside the steady kernel
    /// set (grouped conv, concat/upsample/pixel-shuffle, broadcast, the
    /// RoI gather form) fall back to the allocating [`eval_op`] oracle
    /// and copy into their slot — numerically identical, just not
    /// allocation-free.
    pub fn run_steady(&self, inputs: &[Tensor], ws: &mut Workspace) -> Result<()> {
        #[cfg(feature = "fault-injection")]
        crate::runtime::fault::on_steady_run().map_err(|m| anyhow!(m))?;
        let state: &ExecState = &self.state;
        // Validate sources up front (allocation-free on the success path).
        let mut next_input = 0usize;
        for n in &self.g.nodes {
            match &n.op {
                OpKind::Input => {
                    let t = inputs
                        .get(next_input)
                        .ok_or_else(|| anyhow!("missing input {next_input}"))?;
                    if t.shape() != &n.shape[..] {
                        bail!("input {} shape {:?} != {:?}", next_input, t.shape(), n.shape);
                    }
                    next_input += 1;
                }
                OpKind::Weight => {
                    if self.ws.get(&n.name).is_none() {
                        bail!("weight '{}' missing", n.name);
                    }
                }
                _ => {}
            }
        }
        for &gi in &state.group_order {
            let gr = &self.plan.groups[gi];
            // The running intra-group value lives in one of the two
            // ping-pong buffers; `prev` tracks (node, Some(buf)) for a
            // group-buffer resident, (node, None) for a slot resident.
            let mut prev: Option<(NodeId, Option<usize>)> = None;
            for &id in &gr.nodes {
                let node = self.g.node(id);
                let elems = node.out_elems() as usize;
                let mat = state.materialize[id];
                let inplace = !mat
                    && node.inputs.len() == 1
                    && matches!(prev, Some((pid, Some(_))) if pid == node.inputs[0])
                    && is_inplace_unary(&node.op);
                if inplace {
                    let j = match prev {
                        Some((_, Some(j))) => j,
                        _ => unreachable!(),
                    };
                    apply_unary_slice_inplace(&node.op, &mut ws.group[j][..elems]);
                    prev = Some((id, Some(j)));
                    continue;
                }
                // Take the output buffer out of the arena so its slot can
                // be written while sibling slots are read as arguments.
                let out_place: Option<usize> = if mat {
                    None
                } else {
                    Some(match prev {
                        Some((_, Some(j))) => 1 - j,
                        _ => 0,
                    })
                };
                let mut out_buf = match out_place {
                    None => {
                        let s = state.mplan.slot_of[id]
                            .ok_or_else(|| anyhow!("materialized value {id} has no slot"))?;
                        std::mem::take(&mut ws.slots[s])
                    }
                    Some(j) => std::mem::take(&mut ws.group[j]),
                };
                let res = self.steady_op(
                    id,
                    inputs,
                    &ws.slots,
                    &ws.group,
                    prev,
                    &mut out_buf[..elems],
                    &mut ws.patches,
                    &mut ws.gemm_out,
                    &mut ws.wt,
                    &mut ws.gemm_scratch,
                    &mut ws.qgemm_scratch,
                );
                // Reinstall the buffer before propagating any error so the
                // arena stays structurally intact.
                match out_place {
                    None => {
                        let s = state.mplan.slot_of[id].unwrap();
                        ws.slots[s] = out_buf;
                        prev = Some((id, None));
                    }
                    Some(j) => {
                        ws.group[j] = out_buf;
                        prev = Some((id, Some(j)));
                    }
                }
                res?;
            }
        }
        Ok(())
    }

    /// Evaluate one node into `out` (length = the node's element count),
    /// reading arguments from sources, planned slots or the group
    /// buffers.
    #[allow(clippy::too_many_arguments)]
    fn steady_op(
        &self,
        id: NodeId,
        inputs: &[Tensor],
        slots: &[Vec<f32>],
        group: &[Vec<f32>; 2],
        prev: Option<(NodeId, Option<usize>)>,
        out: &mut [f32],
        patches: &mut [f32],
        gemm_out: &mut [f32],
        wt: &mut [f32],
        gemm_scratch: &mut [f32],
        qgemm_scratch: &mut [i8],
    ) -> Result<()> {
        let state: &ExecState = &self.state;
        let g = self.g;
        let node = g.node(id);
        let elems = out.len();
        match &node.op {
            OpKind::Conv2d { stride, pad, groups: 1, .. } => {
                let (stride, pad) = (*stride, *pad);
                let xid = node
                    .inputs
                    .iter()
                    .copied()
                    .find(|&i| !matches!(g.node(i).op, OpKind::Weight))
                    .ok_or_else(|| anyhow!("conv without data input"))?;
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, xid)?;
                let xs = &g.node(xid).shape;
                let (nb, c, h, w) = (xs[0], xs[1], xs[2], xs[3]);
                if let Some(fkw) = state.fkw.get(&id) {
                    fkw.conv2d_into(x, nb, h, w, state.gemm_cfg.resolved_threads(), out);
                    return Ok(());
                }
                let wid = node
                    .inputs
                    .iter()
                    .copied()
                    .find(|&i| matches!(g.node(i).op, OpKind::Weight))
                    .ok_or_else(|| anyhow!("conv without weight"))?;
                let wshape = &g.node(wid).shape; // [o, i, kh, kw]
                let (o, kh, kw) = (wshape[0], wshape[2], wshape[3]);
                if let Some(rcfg) = state.reuse {
                    let xt = Tensor::from_vec(xs, x.to_vec());
                    let y = if let Some(wtm) = state.packed.reuse_wt.get(&id) {
                        reuse_conv2d_pre(&xt, wtm, kh, kw, stride, pad, &rcfg).0
                    } else {
                        let wten = self
                            .ws
                            .get(&g.node(wid).name)
                            .ok_or_else(|| anyhow!("weight missing"))?;
                        reuse_conv2d(&xt, wten, stride, pad, &rcfg).0
                    };
                    out.copy_from_slice(y.data());
                    return Ok(());
                }
                if let Some(pqb) = state.packed.qconv.get(&id) {
                    // Int8 plan member: quantized filter matrix was packed
                    // at compile time; activations quantize in-flight into
                    // the arena's i8 scratch. Zero allocation, like f32.
                    conv2d_qgemm_prepacked_into(
                        x, nb, c, h, w, pqb, kh, kw, stride, pad, &state.gemm_cfg, patches,
                        gemm_out, qgemm_scratch, out,
                    );
                } else if let Some(pb) = state.packed.conv.get(&id) {
                    conv2d_gemm_prepacked_into(
                        x, nb, c, h, w, pb, kh, kw, stride, pad, &state.gemm_cfg, patches,
                        gemm_out, gemm_scratch, out,
                    );
                } else {
                    let wslice =
                        steady_arg(g, self.ws, state, inputs, slots, group, prev, wid)?;
                    let cols = c * kh * kw;
                    conv_weight_matrix_into(wslice, o, cols, wt);
                    conv2d_gemm_wt_into(
                        x,
                        nb,
                        c,
                        h,
                        w,
                        &wt[..cols * o],
                        o,
                        kh,
                        kw,
                        stride,
                        pad,
                        &state.gemm_cfg,
                        patches,
                        gemm_out,
                        out,
                    );
                }
                Ok(())
            }
            OpKind::Dense => {
                let xid = node
                    .inputs
                    .iter()
                    .copied()
                    .find(|&i| !matches!(g.node(i).op, OpKind::Weight))
                    .ok_or_else(|| anyhow!("dense without data input"))?;
                let wid = node
                    .inputs
                    .iter()
                    .copied()
                    .find(|&i| matches!(g.node(i).op, OpKind::Weight))
                    .ok_or_else(|| anyhow!("dense without weight"))?;
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, xid)?;
                let wshape = &g.node(wid).shape; // [in_f, out_f]
                let (in_f, out_f) = (wshape[0], wshape[1]);
                let rows = x.len() / in_f;
                if let Some(rcfg) = state.reuse {
                    let xt = Tensor::from_vec(&[rows, in_f], x.to_vec());
                    let wten = self
                        .ws
                        .get(&g.node(wid).name)
                        .ok_or_else(|| anyhow!("weight missing"))?;
                    let y = reuse_gemm(&xt, wten, &rcfg).0;
                    out.copy_from_slice(y.data());
                    return Ok(());
                }
                if let Some(pqb) = state.packed.qdense.get(&id) {
                    // Int8 plan member — per-output-channel scales rode in
                    // with the compile-time pack.
                    qgemm_prepacked(
                        rows,
                        x,
                        pqb,
                        &mut out[..rows * out_f],
                        &state.gemm_cfg,
                        qgemm_scratch,
                    );
                } else if let Some(pb) = state.packed.dense.get(&id) {
                    gemm_prepacked(rows, x, pb, &mut out[..rows * out_f], &state.gemm_cfg, gemm_scratch);
                } else {
                    let w = steady_arg(g, self.ws, state, inputs, slots, group, prev, wid)?;
                    gemm(rows, in_f, out_f, x, w, &mut out[..rows * out_f], &state.gemm_cfg);
                }
                Ok(())
            }
            OpKind::BatchNorm => {
                let (xid, wid) = split_data_weight(g, id)?;
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, xid)?;
                let w = steady_arg(g, self.ws, state, inputs, slots, group, prev, wid)?;
                let c = g.node(wid).shape[1];
                bn_into(x, w, c, &g.node(xid).shape, out);
                Ok(())
            }
            OpKind::Bias => {
                let (xid, wid) = split_data_weight(g, id)?;
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, xid)?;
                let w = steady_arg(g, self.ws, state, inputs, slots, group, prev, wid)?;
                let c = w.len();
                let per = per_channel_stride(&g.node(xid).shape, c).0;
                for (i, v) in out.iter_mut().enumerate() {
                    let ch = (i / per) % c;
                    *v = x[i] + w[ch];
                }
                Ok(())
            }
            OpKind::Scale { mul, add } => {
                if node.inputs.len() > 1 {
                    // Per-channel scale via weight (BN inference form).
                    let (xid, wid) = split_data_weight(g, id)?;
                    let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, xid)?;
                    let w = steady_arg(g, self.ws, state, inputs, slots, group, prev, wid)?;
                    let c = g.node(wid).shape[1];
                    bn_into(x, w, c, &g.node(xid).shape, out);
                } else {
                    let x =
                        steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                    let (m, a) = (*mul as f32, *add as f32);
                    for (v, &xv) in out.iter_mut().zip(x) {
                        *v = xv * m + a;
                    }
                }
                Ok(())
            }
            OpKind::Activation(_) | OpKind::Pow { .. } | OpKind::Sqrt => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                out.copy_from_slice(&x[..elems]);
                apply_unary_slice_inplace(&node.op, out);
                Ok(())
            }
            OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div => {
                let a = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                let b = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[1])?;
                if a.len() != elems || b.len() != elems {
                    bail!("elementwise shape mismatch at node {id}");
                }
                match node.op {
                    OpKind::Add => {
                        for ((v, &av), &bv) in out.iter_mut().zip(a).zip(b) {
                            *v = av + bv;
                        }
                    }
                    OpKind::Sub => {
                        for ((v, &av), &bv) in out.iter_mut().zip(a).zip(b) {
                            *v = av - bv;
                        }
                    }
                    OpKind::Mul => {
                        for ((v, &av), &bv) in out.iter_mut().zip(a).zip(b) {
                            *v = av * bv;
                        }
                    }
                    _ => {
                        for ((v, &av), &bv) in out.iter_mut().zip(a).zip(b) {
                            *v = av / bv;
                        }
                    }
                }
                Ok(())
            }
            OpKind::CausalMask => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                let l = *node.shape.last().unwrap();
                out.copy_from_slice(&x[..elems]);
                causal_mask_rows(out, l);
                Ok(())
            }
            OpKind::Softmax => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                let last = *node.shape.last().unwrap();
                out.copy_from_slice(&x[..elems]);
                // Fused masked softmax on the in-arena path: skip the
                // masked upper-triangle columns entirely (no exp over
                // -inf), preserving the zero-allocation guarantee.
                if matches!(g.node(node.inputs[0]).op, OpKind::CausalMask) {
                    causal_softmax_rows(out, last);
                } else {
                    softmax_rows_inplace(out, last);
                }
                Ok(())
            }
            OpKind::LayerNorm => {
                let (xid, wid) = split_data_weight(g, id)?;
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, xid)?;
                let w = steady_arg(g, self.ws, state, inputs, slots, group, prev, wid)?;
                let d = *node.shape.last().unwrap();
                let rows = elems / d;
                out.copy_from_slice(&x[..elems]);
                for r in 0..rows {
                    let row = &mut out[r * d..(r + 1) * d];
                    let mean: f32 = row.iter().sum::<f32>() / d as f32;
                    let var: f32 =
                        row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
                    let inv = 1.0 / (var + 1e-5).sqrt();
                    for (i, v) in row.iter_mut().enumerate() {
                        *v = (*v - mean) * inv * w[i] + w[d + i];
                    }
                }
                Ok(())
            }
            OpKind::MaxPool { k, stride, pad } => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                let xs = &g.node(node.inputs[0]).shape;
                max_pool_into(x, xs[0], xs[1], xs[2], xs[3], *k, *stride, *pad, out);
                Ok(())
            }
            OpKind::AvgPool { k, stride, pad } => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                let xs = &g.node(node.inputs[0]).shape;
                avg_pool_into(x, xs[0], xs[1], xs[2], xs[3], *k, *stride, *pad, out);
                Ok(())
            }
            // ---- transformer set: every op of the attention path
            // (QK^T → scale → softmax → AV), the token-embedding front and
            // the movement ops run *in-arena* — sliced operands in, arena
            // buffer out, per-batch GEMMs on the session's blocked
            // micro-kernel and worker pool. The movement/lookup kernels
            // are allocation-free; MatMul needs no *dedicated* workspace
            // buffers but `gemm` still packs its panels internally, so
            // batched matmul is not yet part of the zero-allocation
            // guarantee (ROADMAP: prepacked/allocation-free attention
            // GEMMs; the counting-allocator property in tests/steady.rs
            // pins the conv/dense demo-cnn path only).
            OpKind::MatMul => {
                let (aid, bid) = (node.inputs[0], node.inputs[1]);
                let a = steady_arg(g, self.ws, state, inputs, slots, group, prev, aid)?;
                let b = steady_arg(g, self.ws, state, inputs, slots, group, prev, bid)?;
                let (batch, m, k, n, bb) =
                    batched_matmul_dims(&g.node(aid).shape, &g.node(bid).shape)?;
                if state.quant.contains(&id) {
                    // Quantized attention contraction: both operands are
                    // activations, so scales are dynamic per batch slice.
                    batched_qmatmul_into(a, b, batch, m, k, n, bb, &state.gemm_cfg, out);
                } else {
                    batched_matmul_into(a, b, batch, m, k, n, bb, &state.gemm_cfg, out);
                }
                Ok(())
            }
            OpKind::Transpose { perm } => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                transpose_into(x, &g.node(node.inputs[0]).shape, perm, out);
                Ok(())
            }
            OpKind::Slice { start } => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                slice_into(x, &g.node(node.inputs[0]).shape, start, &node.shape, out);
                Ok(())
            }
            OpKind::Pad { before, .. } => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                pad_into(x, &g.node(node.inputs[0]).shape, before, &node.shape, out);
                Ok(())
            }
            OpKind::Embedding | OpKind::Gather
                if node.inputs.len() == 2 && g.node(node.inputs[1]).shape.len() == 2 =>
            {
                let ids = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                let table =
                    steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[1])?;
                let ts = &g.node(node.inputs[1]).shape;
                embedding_into(ids, table, ts[0], ts[1], out)
            }
            OpKind::GlobalAvgPool => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                let xs = &g.node(node.inputs[0]).shape;
                gap_into(x, xs[0], xs[1], xs[2], xs[3], out);
                Ok(())
            }
            OpKind::Reshape | OpKind::Flatten => {
                let x = steady_arg(g, self.ws, state, inputs, slots, group, prev, node.inputs[0])?;
                out.copy_from_slice(&x[..elems]);
                Ok(())
            }
            _ => self.steady_fallback(id, inputs, slots, group, prev, out),
        }
    }

    /// Allocating fallback for ops outside the steady kernel set: rebuild
    /// argument tensors, run the reference [`eval_op`], copy the result
    /// into the arena. Correct for every supported op, just not
    /// allocation-free.
    fn steady_fallback(
        &self,
        id: NodeId,
        inputs: &[Tensor],
        slots: &[Vec<f32>],
        group: &[Vec<f32>; 2],
        prev: Option<(NodeId, Option<usize>)>,
        out: &mut [f32],
    ) -> Result<()> {
        let g = self.g;
        let node = g.node(id);
        let mut argts: Vec<Tensor> = Vec::with_capacity(node.inputs.len());
        for &i in &node.inputs {
            let s = steady_arg(g, self.ws, &self.state, inputs, slots, group, prev, i)?;
            argts.push(Tensor::from_vec(&g.node(i).shape, s.to_vec()));
        }
        let refs: Vec<&Tensor> = argts.iter().collect();
        let y = eval_op(g, id, &refs)?;
        out.copy_from_slice(y.data());
        Ok(())
    }
}

/// Resolve one argument of a steady-state op to a flat slice: Input nodes
/// from the caller's tensors, Weight nodes from the store, materialized
/// compute values from their planned slot, the running intra-group value
/// from its ping-pong buffer.
#[allow(clippy::too_many_arguments)]
fn steady_arg<'a>(
    g: &Graph,
    wstore: &'a WeightStore,
    state: &ExecState,
    inputs: &'a [Tensor],
    slots: &'a [Vec<f32>],
    group: &'a [Vec<f32>; 2],
    prev: Option<(NodeId, Option<usize>)>,
    i: NodeId,
) -> Result<&'a [f32]> {
    let n = g.node(i);
    match &n.op {
        OpKind::Input => {
            let idx = state.input_pos[i];
            inputs
                .get(idx)
                .map(|t| t.data())
                .ok_or_else(|| anyhow!("missing input {idx}"))
        }
        OpKind::Weight => wstore
            .get(&n.name)
            .map(|t| t.data())
            .ok_or_else(|| anyhow!("weight '{}' missing", n.name)),
        _ => {
            let elems = n.out_elems() as usize;
            if state.materialize[i] {
                if let Some(s) = state.mplan.slot_of[i] {
                    return Ok(&slots[s][..elems]);
                }
            }
            if let Some((pid, Some(j))) = prev {
                if pid == i {
                    return Ok(&group[j][..elems]);
                }
            }
            bail!("input {i} not materialized — fusion order is not topological")
        }
    }
}

/// The unary ops the fused engines apply in place on the running buffer.
fn is_inplace_unary(op: &OpKind) -> bool {
    matches!(
        op,
        OpKind::Activation(_) | OpKind::Scale { .. } | OpKind::Pow { .. } | OpKind::Sqrt
    )
}

/// Per-channel scale+shift into `out` (BatchNorm inference form;
/// `w = [2, c]` flattened).
fn bn_into(x: &[f32], w: &[f32], c: usize, xshape: &[usize], out: &mut [f32]) {
    let per = per_channel_stride(xshape, c).0;
    for (i, v) in out.iter_mut().enumerate() {
        let ch = (i / per) % c;
        *v = x[i] * w[ch] + w[c + ch];
    }
}

/// General k×k/stride max pool with symmetric zero padding over flat NCHW
/// into `out` (the `{k:2, stride:2}`-only special case is gone — the
/// window max is taken over in-bounds taps, so padding never wins).
#[allow(clippy::too_many_arguments)]
fn max_pool_into(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut [f32],
) {
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    for b in 0..n {
        for ci in 0..c {
            let in_base = (b * c + ci) * h * w;
            let out_base = (b * c + ci) * oh * ow;
            for y in 0..oh {
                for xx in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..k {
                        let iy = (y * stride + dy) as isize - pad as isize;
                        if iy < 0 || iy as usize >= h {
                            continue;
                        }
                        for dx in 0..k {
                            let ix = (xx * stride + dx) as isize - pad as isize;
                            if ix < 0 || ix as usize >= w {
                                continue;
                            }
                            m = m.max(x[in_base + iy as usize * w + ix as usize]);
                        }
                    }
                    out[out_base + y * ow + xx] = m;
                }
            }
        }
    }
}

/// k×k/stride average pool with symmetric zero padding over flat NCHW into
/// `out` (windows average over in-bounds taps only, matching [`eval_op`]).
#[allow(clippy::too_many_arguments)]
fn avg_pool_into(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    stride: usize,
    pad: usize,
    out: &mut [f32],
) {
    let oh = (h + 2 * pad - k) / stride + 1;
    let ow = (w + 2 * pad - k) / stride + 1;
    for b in 0..n {
        for ci in 0..c {
            let in_base = (b * c + ci) * h * w;
            let out_base = (b * c + ci) * oh * ow;
            for y in 0..oh {
                for xx in 0..ow {
                    let mut s = 0.0;
                    let mut cnt = 0;
                    for dy in 0..k {
                        let iy = (y * stride + dy) as isize - pad as isize;
                        if iy < 0 || iy as usize >= h {
                            continue;
                        }
                        for dx in 0..k {
                            let ix = (xx * stride + dx) as isize - pad as isize;
                            if ix < 0 || ix as usize >= w {
                                continue;
                            }
                            s += x[in_base + iy as usize * w + ix as usize];
                            cnt += 1;
                        }
                    }
                    out[out_base + y * ow + xx] = s / cnt.max(1) as f32;
                }
            }
        }
    }
}

/// Causal mask over the trailing `l × l` matrices of `data`: entries with
/// key index `j > i` (strictly above the diagonal of each square block)
/// become `-inf`. This is the reference semantics of [`OpKind::CausalMask`]
/// — the fused softmax kernels below never materialize these values.
fn causal_mask_rows(data: &mut [f32], l: usize) {
    debug_assert!(l > 0 && data.len() % (l * l) == 0);
    for block in data.chunks_exact_mut(l * l) {
        for (i, row) in block.chunks_exact_mut(l).enumerate() {
            for v in &mut row[i + 1..] {
                *v = f32::NEG_INFINITY;
            }
        }
    }
}

/// Plain row softmax in place over `[rows, l]`-flattened data.
fn softmax_rows_inplace(data: &mut [f32], l: usize) {
    for row in data.chunks_exact_mut(l) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut s = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            s += *v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
}

/// Fused causal masked softmax in place over `[rows, l]`-flattened scores:
/// query row `i` (its index within each `l × l` block) normalizes over the
/// allowed prefix `0..=i` and the masked tail is written as exact zeros —
/// the masked columns are *skipped*, never exponentiated. Bitwise
/// identical to `causal_mask_rows` + [`softmax_rows_inplace`]
/// (`exp(-inf − mx) == 0` and `-inf` never wins the row max, since the
/// diagonal is always allowed).
fn causal_softmax_rows(data: &mut [f32], l: usize) {
    debug_assert!(l > 0 && data.len() % (l * l) == 0);
    for (r, row) in data.chunks_exact_mut(l).enumerate() {
        let allowed = (r % l) + 1;
        let (live, masked) = row.split_at_mut(allowed);
        let mx = live.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut s = 0.0;
        for v in live.iter_mut() {
            *v = (*v - mx).exp();
            s += *v;
        }
        for v in live.iter_mut() {
            *v /= s;
        }
        masked.fill(0.0);
    }
}

/// Global average pool `[n,c,h,w] -> [n,c]` into `out`.
fn gap_into(x: &[f32], n: usize, c: usize, h: usize, w: usize, out: &mut [f32]) {
    let denom = (h * w) as f32;
    for b in 0..n {
        for ci in 0..c {
            let in_base = (b * c + ci) * h * w;
            let mut s = 0.0;
            for y in 0..h {
                for xx in 0..w {
                    s += x[in_base + y * w + xx];
                }
            }
            out[b * c + ci] = s / denom;
        }
    }
}

/// Both data+weight binary forms (BN, Bias, per-channel Scale, LayerNorm)
/// share this input split.
fn split_data_weight(g: &Graph, id: NodeId) -> Result<(NodeId, NodeId)> {
    let n = g.node(id);
    let xid = n
        .inputs
        .iter()
        .copied()
        .find(|&i| !matches!(g.node(i).op, OpKind::Weight))
        .ok_or_else(|| anyhow!("op '{}' without data input", n.op.name()))?;
    let wid = n
        .inputs
        .iter()
        .copied()
        .find(|&i| matches!(g.node(i).op, OpKind::Weight))
        .ok_or_else(|| anyhow!("op '{}' without weight input", n.op.name()))?;
    Ok((xid, wid))
}

/// Look up a node's current value: sources come from their backing
/// storage (caller inputs / weight store), compute nodes from their
/// planned slot.
fn planned_value<'a>(
    mplan: &MemoryPlan,
    slots: &'a [Option<Tensor>],
    src: &[Option<&'a Tensor>],
    id: NodeId,
) -> Option<&'a Tensor> {
    if let Some(t) = src[id] {
        return Some(t);
    }
    mplan.slot_of[id].and_then(|s| slots[s].as_ref())
}

fn apply_unary_inplace(op: &OpKind, t: &mut Tensor) {
    apply_unary_slice_inplace(op, t.data_mut());
}

fn apply_unary_slice_inplace(op: &OpKind, s: &mut [f32]) {
    match op {
        OpKind::Activation(a) => {
            let f = act_fn(*a);
            for v in s {
                *v = f(*v);
            }
        }
        OpKind::Scale { mul, add } => {
            let (m, a) = (*mul as f32, *add as f32);
            for v in s {
                *v = *v * m + a;
            }
        }
        OpKind::Pow { e } => {
            let e = *e as f32;
            for v in s {
                *v = v.powf(e);
            }
        }
        OpKind::Sqrt => {
            // IEEE: sqrt(negative) is NaN, same as the eval_op kernel.
            for v in s {
                *v = v.sqrt();
            }
        }
        _ => unreachable!("not a unary in-place op"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{fuse, FusionConfig};
    use crate::graph::zoo::NetBuilder;
    use crate::pruning::pattern::{apply_assignment, assign_patterns, PatternSet};
    use crate::util::proptest_lite::forall;
    use crate::util::rng::Rng;

    /// A small CNN covering conv/bn/act/pool/residual/gap/dense.
    fn demo_cnn() -> Graph {
        let mut b = NetBuilder::new("demo", &[1, 3, 16, 16]);
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        let skip = b.cur();
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        let t = b.cur();
        b.add_residual(skip, t);
        b.maxpool(2, 2, 0);
        b.gap();
        b.dense(10);
        b.finish()
    }

    #[test]
    fn executor_runs_demo_cnn() {
        let g = demo_cnn();
        let mut rng = Rng::new(51);
        let ws = WeightStore::init_random(&g, &mut rng);
        let x = Tensor::randn(&[1, 3, 16, 16], 1.0, &mut rng);
        let y = Executor::new(&g, &ws).run(&[x]).unwrap();
        assert_eq!(y[0].shape(), &[1, 10]);
        assert!(y[0].data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fused_executor_matches_reference() {
        forall("fused == reference on demo CNN", 8, |rng| {
            let g = demo_cnn();
            let ws = WeightStore::init_random(&g, &mut rng.fork());
            let x = Tensor::randn(&[1, 3, 16, 16], 1.0, rng);
            let a = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();
            let plan = fuse(&g, &FusionConfig::default());
            let b = FusedExecutor::new(&g, &ws, &plan).run(&[x]).unwrap();
            assert!(
                a[0].max_abs_diff(&b[0]) < 1e-4,
                "fused diverges: {}",
                a[0].max_abs_diff(&b[0])
            );
        });
    }

    #[test]
    fn memory_planner_pools_buffers_without_changing_results() {
        let g = demo_cnn();
        let mut rng = Rng::new(57);
        let ws = WeightStore::init_random(&g, &mut rng);
        let x = Tensor::randn(&[1, 3, 16, 16], 1.0, &mut rng);
        let reference = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();
        let plan = fuse(&g, &FusionConfig::default());
        let (fused, stats) = FusedExecutor::new(&g, &ws, &plan)
            .run_with_stats(&[x])
            .unwrap();
        assert!(reference[0].max_abs_diff(&fused[0]) < 1e-4);
        assert!(
            stats.slots < stats.planned_values,
            "planner did not pool: {} slots for {} materialized values",
            stats.slots,
            stats.planned_values
        );
        assert!(stats.bytes_pooled < stats.bytes_one_per_node);
    }

    #[test]
    fn fkw_path_matches_dense_pruned() {
        let mut rng = Rng::new(53);
        let mut b = NetBuilder::new("p", &[1, 4, 12, 12]);
        let conv_id = b.conv(8, 3, 1, 1, 1);
        b.act(Act::Relu);
        let g = b.finish();
        let mut ws = WeightStore::init_random(&g, &mut rng);
        // Pattern-prune the conv weight.
        let wname = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, OpKind::Weight))
            .unwrap()
            .name
            .clone();
        let w = ws.get(&wname).unwrap().clone();
        let asg = assign_patterns(&w, &PatternSet::elite8());
        ws.set(&wname, apply_assignment(&w, &asg));
        let x = Tensor::randn(&[1, 4, 12, 12], 1.0, &mut rng);
        let dense = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();
        let plan = fuse(&g, &FusionConfig::default());
        let mut fx = FusedExecutor::new(&g, &ws, &plan);
        fx.attach_fkw(conv_id, &asg).unwrap();
        let fused = fx.run(&[x]).unwrap();
        assert!(dense[0].max_abs_diff(&fused[0]) < 1e-4);
    }

    #[test]
    fn prebuilt_state_matches_fresh_construction() {
        let g = demo_cnn();
        let mut rng = Rng::new(58);
        let ws = WeightStore::init_random(&g, &mut rng);
        let plan = fuse(&g, &FusionConfig::default());
        let state = ExecState::new(&g, &plan);
        let x = Tensor::randn(&[1, 3, 16, 16], 1.0, &mut rng);
        let a = FusedExecutor::new(&g, &ws, &plan).run(&[x.clone()]).unwrap();
        let b = FusedExecutor::with_state(&g, &ws, &plan, &state)
            .run(&[x])
            .unwrap();
        assert_eq!(a[0].data(), b[0].data());
        assert!(state.plan_stats().slots <= state.plan_stats().planned_values);
    }

    #[test]
    fn deep_reuse_routing_stays_close_to_exact() {
        use crate::deepreuse::ReuseConfig;
        let g = demo_cnn();
        let mut rng = Rng::new(59);
        let ws = WeightStore::init_random(&g, &mut rng);
        let plan = fuse(&g, &FusionConfig::default());
        let x = Tensor::randn(&[1, 3, 16, 16], 1.0, &mut rng);
        let exact = FusedExecutor::new(&g, &ws, &plan).run(&[x.clone()]).unwrap();
        let mut fx = FusedExecutor::new(&g, &ws, &plan);
        // Tight clustering so the LSH approximation is near-exact.
        fx.set_reuse(Some(ReuseConfig { hash_bits: 12, max_rel_dev: 0.02, ..Default::default() }));
        let approx = fx.run(&[x]).unwrap();
        let scale = exact[0].data().iter().map(|v| v.abs()).sum::<f32>()
            / exact[0].len() as f32;
        let rel = approx[0].mad(&exact[0]) / scale.max(1e-6);
        assert!(rel < 0.05, "deep-reuse routing diverges: rel err {rel}");
    }

    /// The steady-state workspace engine matches the Tensor engine (and
    /// thus the reference executor) on the demo CNN, with and without
    /// pre-packed weights, and is bitwise-stable across repeated runs of
    /// the same arena.
    #[test]
    fn steady_engine_matches_tensor_engine() {
        let g = demo_cnn();
        let mut rng = Rng::new(71);
        let ws = WeightStore::init_random(&g, &mut rng);
        let plan = fuse(&g, &FusionConfig::default());
        let x = Tensor::randn(&[1, 3, 16, 16], 1.0, &mut rng);
        let out_id = g.outputs[0];
        let elems = g.node(out_id).out_elems() as usize;
        for prepack in [false, true] {
            let mut state = ExecState::new(&g, &plan);
            if prepack {
                let packed = state.prepack(&g, &ws).unwrap();
                assert!(packed > 0, "nothing prepacked on demo CNN");
                assert!(state.packed_stats().1 > 0);
            }
            let fx = FusedExecutor::with_state(&g, &ws, &plan, &state);
            let want = fx.run(&[x.clone()]).unwrap();
            let mut arena = state.workspace();
            fx.run_steady(&[x.clone()], &mut arena).unwrap();
            let got = state.planned_slice(&arena, out_id, elems).unwrap().to_vec();
            let d = want[0]
                .data()
                .iter()
                .zip(&got)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(d < 1e-4, "steady (prepack={prepack}) diverges by {d}");
            // Steady state is deterministic: re-running over the same
            // arena reproduces the output bitwise.
            fx.run_steady(&[x.clone()], &mut arena).unwrap();
            let again = state.planned_slice(&arena, out_id, elems).unwrap();
            assert_eq!(&got[..], again, "steady engine not bitwise-stable");
        }
    }

    /// FKW and deep-reuse routing work inside the steady engine too.
    #[test]
    fn steady_engine_routes_fkw_and_reuse() {
        use crate::deepreuse::ReuseConfig;
        let mut rng = Rng::new(72);
        let mut b = NetBuilder::new("p", &[1, 4, 12, 12]);
        let conv_id = b.conv(8, 3, 1, 1, 1);
        b.act(Act::Relu);
        let g = b.finish();
        let mut ws = WeightStore::init_random(&g, &mut rng);
        let wname = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, OpKind::Weight))
            .unwrap()
            .name
            .clone();
        let w = ws.get(&wname).unwrap().clone();
        let asg = assign_patterns(&w, &PatternSet::elite8());
        ws.set(&wname, apply_assignment(&w, &asg));
        let x = Tensor::randn(&[1, 4, 12, 12], 1.0, &mut rng);
        let plan = fuse(&g, &FusionConfig::default());
        let out_id = g.outputs[0];
        let elems = g.node(out_id).out_elems() as usize;

        // FKW route.
        let mut state = ExecState::new(&g, &plan);
        state.attach_fkw(&g, &ws, conv_id, &asg).unwrap();
        state.prepack(&g, &ws).unwrap();
        let fx = FusedExecutor::with_state(&g, &ws, &plan, &state);
        let want = fx.run(&[x.clone()]).unwrap();
        let mut arena = state.workspace();
        fx.run_steady(&[x.clone()], &mut arena).unwrap();
        let got = state.planned_slice(&arena, out_id, elems).unwrap();
        let d = want[0]
            .data()
            .iter()
            .zip(got)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 1e-4, "steady fkw route diverges by {d}");

        // Deep-reuse route (tight clustering ≈ exact), with the transposed
        // weight cached at prepack time.
        let mut state = ExecState::new(&g, &plan);
        state.set_reuse(Some(ReuseConfig {
            hash_bits: 12,
            max_rel_dev: 0.02,
            ..Default::default()
        }));
        state.prepack(&g, &ws).unwrap();
        let fx = FusedExecutor::with_state(&g, &ws, &plan, &state);
        let want = fx.run(&[x.clone()]).unwrap();
        let mut arena = state.workspace();
        fx.run_steady(&[x], &mut arena).unwrap();
        let got = state.planned_slice(&arena, out_id, elems).unwrap();
        let scale =
            want[0].data().iter().map(|v| v.abs()).sum::<f32>() / want[0].len() as f32;
        let mad = want[0]
            .data()
            .iter()
            .zip(got)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / want[0].len() as f32;
        assert!(mad / scale.max(1e-6) < 0.05, "steady reuse route diverges");
    }

    #[test]
    fn depthwise_conv_supported() {
        let mut b = NetBuilder::new("dw", &[1, 4, 8, 8]);
        b.dwconv(3, 1, 1);
        b.act(Act::Relu);
        let g = b.finish();
        let mut rng = Rng::new(54);
        let ws = WeightStore::init_random(&g, &mut rng);
        let x = Tensor::randn(&[1, 4, 8, 8], 1.0, &mut rng);
        let y = Executor::new(&g, &ws).run(&[x]).unwrap();
        assert_eq!(y[0].shape(), &[1, 4, 8, 8]);
    }

    #[test]
    fn wdsr_like_pixel_shuffle_path() {
        let mut b = NetBuilder::new("sr", &[1, 3, 8, 8]);
        b.conv(12, 3, 1, 1, 1);
        b.pixel_shuffle(2);
        let g = b.finish();
        let mut rng = Rng::new(55);
        let ws = WeightStore::init_random(&g, &mut rng);
        let x = Tensor::randn(&[1, 3, 8, 8], 1.0, &mut rng);
        let y = Executor::new(&g, &ws).run(&[x]).unwrap();
        assert_eq!(y[0].shape(), &[1, 3, 16, 16]);
    }

    #[test]
    fn unsupported_op_errors_cleanly() {
        let mut g = Graph::new("bad");
        let x = g.input("x", &[1, 4]);
        let gth = g.add("g", OpKind::Gather, vec![x], vec![1, 4]);
        g.outputs = vec![gth];
        let ws = WeightStore::new();
        let err = Executor::new(&g, &ws)
            .run(&[Tensor::zeros(&[1, 4])])
            .unwrap_err();
        assert!(err.to_string().contains("gather"));
    }

    #[test]
    fn rewrite_preserves_semantics_with_weight_store() {
        use crate::rewrite::{rewrite, RewriteConfig};
        // dense-dense + scale + identity chain, rewritten with weights.
        forall("rewrite preserves numerics", 10, |rng| {
            let mut b = NetBuilder::new("rw", &[1, 6]);
            b.dense(12);
            b.dense(4);
            let mut g = b.finish();
            // Append a scale and an identity reshape.
            let s = g.add(
                "post_scale",
                OpKind::Scale { mul: 0.5, add: 0.0 },
                vec![g.outputs[0]],
                vec![1, 4],
            );
            let r = g.add("noop_reshape", OpKind::Reshape, vec![s], vec![1, 4]);
            g.outputs = vec![r];
            let ws = WeightStore::init_random(&g, &mut rng.fork());
            let x = Tensor::randn(&[1, 6], 1.0, rng);
            let before = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();
            let mut g2 = g.clone();
            let mut ws2 = ws.clone();
            rewrite(&mut g2, Some(&mut ws2), &RewriteConfig::default());
            let after = Executor::new(&g2, &ws2).run(&[x]).unwrap();
            assert!(
                before[0].max_abs_diff(&after[0]) < 1e-4,
                "rewrite changed numerics by {}",
                before[0].max_abs_diff(&after[0])
            );
            assert!(g2.operator_count() < g.operator_count());
        });
    }

    /// Satellite regression: `Sqrt` propagates NaN for negative inputs per
    /// IEEE instead of clamping to 0 — on both the eval_op kernel and the
    /// in-place fused/steady kernel.
    #[test]
    fn sqrt_propagates_nan_per_ieee() {
        let mut g = Graph::new("sq");
        let x = g.input("x", &[4]);
        let s = g.add("sqrt", OpKind::Sqrt, vec![x], vec![4]);
        g.outputs = vec![s];
        let ws = WeightStore::new();
        let xin = Tensor::from_vec(&[4], vec![4.0, 0.0, -1.0, -0.25]);
        let y = Executor::new(&g, &ws).run(&[xin]).unwrap();
        assert_eq!(y[0].data()[0], 2.0);
        assert_eq!(y[0].data()[1], 0.0);
        assert!(y[0].data()[2].is_nan(), "sqrt(-1) must be NaN, got {}", y[0].data()[2]);
        assert!(y[0].data()[3].is_nan());
        let mut buf = vec![9.0f32, -9.0];
        apply_unary_slice_inplace(&OpKind::Sqrt, &mut buf);
        assert_eq!(buf[0], 3.0);
        assert!(buf[1].is_nan(), "in-place sqrt kernel still clamps");
    }

    /// Satellite regression: pooling with k ≠ stride uses windowed
    /// `(h−k)/stride+1` output semantics in builder + executor (the old
    /// shape was `h/stride`, silently wrong for e.g. k=3, s=1).
    #[test]
    fn pools_with_k_ne_stride_use_windowed_shapes() {
        let mut rng = Rng::new(61);
        for (k, stride, pad) in [(3usize, 1usize, 0usize), (3, 1, 1), (3, 2, 1), (5, 1, 2), (2, 1, 0)] {
            let mut b = NetBuilder::new("p", &[1, 2, 8, 8]);
            b.avgpool(k, stride, pad);
            let g = b.finish();
            let want_hw = (8 + 2 * pad - k) / stride + 1;
            assert_eq!(
                g.node(g.outputs[0]).shape,
                vec![1, 2, want_hw, want_hw],
                "builder shape for k={k} s={stride} p={pad}"
            );
            let ws = WeightStore::new();
            let x = Tensor::randn(&[1, 2, 8, 8], 1.0, &mut rng);
            let y = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();
            assert_eq!(y[0].shape(), &[1, 2, want_hw, want_hw]);
            // Hand-rolled window average at one interior site.
            let mut s = 0.0;
            let mut cnt = 0;
            for dy in 0..k {
                for dx in 0..k {
                    let iy = dy as isize - pad as isize;
                    let ix = dx as isize - pad as isize;
                    if iy >= 0 && ix >= 0 && (iy as usize) < 8 && (ix as usize) < 8 {
                        s += x.at(&[0, 1, iy as usize, ix as usize]);
                        cnt += 1;
                    }
                }
            }
            let d = (y[0].at(&[0, 1, 0, 0]) - s / cnt as f32).abs();
            assert!(d < 1e-5, "avg window wrong for k={k} s={stride} p={pad}: {d}");
        }
    }

    /// Satellite: the general max-pool kernel replaces the {k:2, s:2}
    /// special case — it must agree with the old `maxpool2` on that shape
    /// and produce correct maxima for k ≠ stride.
    #[test]
    fn general_maxpool_kernel() {
        let mut rng = Rng::new(62);
        let x = Tensor::randn(&[2, 3, 8, 8], 1.0, &mut rng);
        let got = max_pool(&x, 2, 2, 0);
        assert_eq!(got.data(), x.maxpool2().data(), "k=2/s=2 diverges from maxpool2");
        // k=3, s=1, pad=1: same-size output; interior site is a 3x3 max.
        let y = max_pool(&x, 3, 1, 1);
        assert_eq!(y.shape(), &[2, 3, 8, 8]);
        let mut m = f32::NEG_INFINITY;
        for dy in 0..3 {
            for dx in 0..3 {
                m = m.max(x.at(&[1, 2, 2 + dy, 3 + dx]));
            }
        }
        assert_eq!(y.at(&[1, 2, 3, 4]), m);
        // Executor path with a k≠stride pool.
        let mut b = NetBuilder::new("mp", &[1, 2, 9, 9]);
        b.maxpool(3, 2, 1);
        let g = b.finish();
        let x = Tensor::randn(&[1, 2, 9, 9], 1.0, &mut rng);
        let y = Executor::new(&g, &WeightStore::new()).run(&[x]).unwrap();
        assert_eq!(y[0].shape(), &[1, 2, 5, 5]);
    }

    /// The movement kernels: general transpose (rank 2/3/4 perms), slice
    /// crop and zero pad, checked against hand indexing.
    #[test]
    fn movement_kernels_match_hand_indexing() {
        let mut rng = Rng::new(63);
        let x = Tensor::randn(&[2, 3, 4, 5], 1.0, &mut rng);
        // Head-split style perm [0,2,1,3].
        let t = transpose_nd(&x, &[0, 2, 1, 3]);
        assert_eq!(t.shape(), &[2, 4, 3, 5]);
        for a in 0..2 {
            for bb in 0..3 {
                for c in 0..4 {
                    for d in 0..5 {
                        assert_eq!(t.at(&[a, c, bb, d]), x.at(&[a, bb, c, d]));
                    }
                }
            }
        }
        // Last-two swap [0,1,3,2] (the K^T form).
        let t = transpose_nd(&x, &[0, 1, 3, 2]);
        assert_eq!(t.shape(), &[2, 3, 5, 4]);
        assert_eq!(t.at(&[1, 2, 4, 3]), x.at(&[1, 2, 3, 4]));
        // Matrix transpose round-trips.
        let m = Tensor::randn(&[7, 3], 1.0, &mut rng);
        let mt = transpose_nd(&m, &[1, 0]);
        assert_eq!(transpose_nd(&mt, &[1, 0]).data(), m.data());

        // Slice: a [1,2,2] window starting at [1,1,2].
        let s = slice_crop(&x.reshape(&[2, 3, 20]), &[1, 1, 2], &[1, 2, 2]);
        assert_eq!(s.shape(), &[1, 2, 2]);
        let xr = x.reshape(&[2, 3, 20]);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(s.at(&[0, i, j]), xr.at(&[1, 1 + i, 2 + j]));
            }
        }

        // Pad: zeros outside, payload shifted by `before`.
        let p = pad_zero(&m, &[1, 2], &[0, 1]);
        assert_eq!(p.shape(), &[8, 6]);
        assert_eq!(p.at(&[0, 0]), 0.0);
        assert_eq!(p.at(&[1, 2]), m.at(&[0, 0]));
        assert_eq!(p.at(&[7, 4]), m.at(&[6, 2]));
        assert_eq!(p.at(&[7, 5]), 0.0);
        let total: f32 = p.data().iter().sum();
        let want: f32 = m.data().iter().sum();
        assert!((total - want).abs() < 1e-4, "pad invented mass");
    }

    /// Embedding row lookup: correct rows, loud errors on bad ids.
    #[test]
    fn embedding_lookup_rows_and_errors() {
        let table = Tensor::from_vec(&[3, 2], vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        let ids = Tensor::from_vec(&[2, 2], vec![2.0, 0.0, 1.0, 2.0]);
        let y = embedding_lookup(&ids, &table).unwrap();
        assert_eq!(y.shape(), &[2, 2, 2]);
        assert_eq!(y.data(), &[20.0, 21.0, 0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        assert!(embedding_lookup(&Tensor::from_vec(&[1], vec![3.0]), &table).is_err());
        assert!(embedding_lookup(&Tensor::from_vec(&[1], vec![-1.0]), &table).is_err());
        assert!(embedding_lookup(&Tensor::from_vec(&[1], vec![0.5]), &table).is_err());
    }

    /// The fused masked-softmax kernel (skip masked columns) is bitwise
    /// identical to the reference semantics (mask to -inf, then the plain
    /// row softmax), including the seq=1 edge case.
    #[test]
    fn causal_softmax_skip_kernel_matches_minus_inf_reference() {
        let mut rng = Rng::new(91);
        for l in [1usize, 2, 5, 8] {
            let x = Tensor::randn(&[3, l, l], 1.0, &mut rng);
            let mut reference = x.data().to_vec();
            causal_mask_rows(&mut reference, l);
            softmax_rows_inplace(&mut reference, l);
            let mut fused = x.data().to_vec();
            causal_softmax_rows(&mut fused, l);
            assert_eq!(reference, fused, "l={l}: skip kernel diverges");
            // Masked positions are exact zeros; every row sums to 1.
            for b in 0..3 {
                for i in 0..l {
                    let row = &fused[(b * l + i) * l..(b * l + i + 1) * l];
                    for (j, &v) in row.iter().enumerate() {
                        if j > i {
                            assert_eq!(v, 0.0, "masked [{b},{i},{j}] leaked");
                        }
                    }
                    let s: f32 = row.iter().sum();
                    assert!((s - 1.0).abs() < 1e-5, "row [{b},{i}] sums to {s}");
                }
            }
        }
    }

    /// Batched matmul over rank-3 and rank-4 leading dims (and the rank-2
    /// broadcast RHS) against a hand-rolled triple loop.
    #[test]
    fn batched_matmul_matches_naive_loops() {
        let mut rng = Rng::new(64);
        // [2, 3, 4, 5] x [2, 3, 5, 6] — the attention shape class.
        let a = Tensor::randn(&[2, 3, 4, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[2, 3, 5, 6], 1.0, &mut rng);
        let y = batched_matmul(&a, &b).unwrap();
        assert_eq!(y.shape(), &[2, 3, 4, 6]);
        for b0 in 0..2 {
            for b1 in 0..3 {
                for i in 0..4 {
                    for j in 0..6 {
                        let mut acc = 0.0f32;
                        for kk in 0..5 {
                            acc += a.at(&[b0, b1, i, kk]) * b.at(&[b0, b1, kk, j]);
                        }
                        let d = (y.at(&[b0, b1, i, j]) - acc).abs();
                        assert!(d < 1e-4, "rank-4 matmul off by {d}");
                    }
                }
            }
        }
        // Rank-2 RHS broadcast: [2, 3, 4, 5] x [5, 6].
        let w = Tensor::randn(&[5, 6], 1.0, &mut rng);
        let y = batched_matmul(&a, &w).unwrap();
        assert_eq!(y.shape(), &[2, 3, 4, 6]);
        let mut acc = 0.0f32;
        for kk in 0..5 {
            acc += a.at(&[1, 2, 3, kk]) * w.at(&[kk, 4]);
        }
        assert!((y.at(&[1, 2, 3, 4]) - acc).abs() < 1e-4);
        // Mismatched inner or leading dims are loud errors.
        assert!(batched_matmul(&a, &Tensor::zeros(&[2, 3, 4, 6])).is_err());
        assert!(batched_matmul(&a, &Tensor::zeros(&[2, 2, 5, 6])).is_err());
    }
}
