//! Incremental autoregressive decoding: the second execution mode of the
//! crate (ISSUE-5 tentpole). A [`DecodeSession`] runs a *causal* decoder
//! graph one token at a time: each step computes the new token's row
//! through every row-wise op (embedding, LayerNorm, dense, FFN,
//! residuals), appends that position's K/V rows **in place** into
//! per-attention cache buffers, and evaluates attention as row-vector
//! products against the cache — `O(L)` work per step instead of the
//! `O(L²)` full-sequence recompute, and semantically identical to a full
//! causal forward pass at the same position (pinned by
//! `tests/decode.rs`).
//!
//! The session is a small shape-specialized interpreter over the
//! (rewritten) graph, built once at construction:
//!
//! * **Constant subgraphs** (weight-only ancestry, e.g. GPT-2's transposed
//!   tied LM-head table or the exporter's `sqrt(d_k)` divisor) are
//!   evaluated once via [`eval_op`] and cached.
//! * **Attention blocks** are discovered structurally by
//!   [`attention_specs`] (`MatMul → [scale/mask]* → Softmax → MatMul`,
//!   shared with the planner's K/V-cache sizing); non-causal attention is
//!   a loud construction error — decoding it incrementally would silently
//!   change semantics.
//! * Every other op is resolved to a slice kernel over pre-allocated
//!   per-node buffers whose shapes substitute the sequence dim with 1
//!   (score-chain nodes keep a *dynamic* key axis = current length).
//!
//! After the first (warm-up) call, [`DecodeSession::step`] performs **no
//! heap allocation on the calling thread** — the counting-allocator test
//! in `tests/steady.rs` pins this.

use anyhow::{anyhow, bail, Result};

use crate::error::XgenError;
use crate::graph::{Graph, NodeId, OpKind, WeightStore};
use crate::tensor::Tensor;

use super::{
    apply_unary_slice_inplace, embedding_into, eval_op, per_channel_stride, softmax_rows_inplace,
    transpose_into,
};

/// One attention block discovered in a graph, in the terms the incremental
/// decoder and the planner's K/V-cache sizing share.
#[derive(Debug, Clone)]
pub struct AttnSpec {
    /// The score MatMul `Q × K^T`.
    pub scores_mm: NodeId,
    /// The transpose feeding the score MatMul's RHS.
    pub kt: NodeId,
    /// Producer of K rows (the transpose's input; one `batch·heads × d_head`
    /// row block per position).
    pub k_src: NodeId,
    /// The softmax over the (masked) scores.
    pub softmax: NodeId,
    /// The context MatMul `probs × V`.
    pub av_mm: NodeId,
    /// Producer of V rows.
    pub v_src: NodeId,
    /// Nodes on the scores → softmax chain (inclusive, topological): their
    /// key axis is the *current* sequence length during decode.
    pub chain: Vec<NodeId>,
    /// Leading batch×heads product of the score tensor.
    pub bh: usize,
    /// Per-head feature dim (the cached row width per head).
    pub dh: usize,
    /// Full-graph sequence length (the maximum cacheable positions).
    pub seq: usize,
    /// Whether an [`OpKind::CausalMask`] sits on the chain.
    pub causal: bool,
}

impl AttnSpec {
    /// Elements of one cached row (K or V) across all heads.
    pub fn row_elems(&self) -> usize {
        self.bh * self.dh
    }
}

/// Find every attention block `MatMul → [scale/mask elementwise]* →
/// Softmax → MatMul` in `g`. Purely structural and total — graphs without
/// attention yield an empty vec, malformed patterns are skipped, nothing
/// panics. Both [`DecodeSession`] and
/// [`WorkspaceSpec`](super::planner::WorkspaceSpec)'s K/V-cache sizing go
/// through this single detector.
pub fn attention_specs(g: &Graph) -> Vec<AttnSpec> {
    let users = g.users();
    let mut specs = Vec::new();
    for s in g.nodes.iter().filter(|n| matches!(n.op, OpKind::Softmax)) {
        // Walk up from the softmax through the elementwise score chain.
        let mut walked = vec![s.id];
        let mut causal = false;
        let mut cur = s.inputs[0];
        let mut found = None;
        for _ in 0..16 {
            let n = g.node(cur);
            match &n.op {
                OpKind::MatMul => {
                    found = Some(cur);
                    break;
                }
                OpKind::CausalMask => {
                    causal = true;
                    walked.push(cur);
                    cur = n.inputs[0];
                }
                OpKind::Scale { .. } | OpKind::Pow { .. } | OpKind::Sqrt
                | OpKind::Activation(_) => {
                    walked.push(cur);
                    cur = n.inputs[0];
                }
                OpKind::Div | OpKind::Mul | OpKind::Add | OpKind::Sub => {
                    // The data side of the chain: skip scalar-constant
                    // operands (a Broadcast of the sqrt(d_k) divisor, a
                    // bare weight).
                    walked.push(cur);
                    let data = n.inputs.iter().copied().find(|&i| {
                        !matches!(g.node(i).op, OpKind::Broadcast | OpKind::Weight)
                    });
                    match data {
                        Some(d) => cur = d,
                        None => break,
                    }
                }
                _ => break,
            }
        }
        let Some(scores_mm) = found else { continue };
        let mm = g.node(scores_mm);
        if mm.inputs.len() != 2 {
            continue;
        }
        let (q, kt) = (mm.inputs[0], mm.inputs[1]);
        let ktn = g.node(kt);
        if !matches!(ktn.op, OpKind::Transpose { .. }) || ktn.shape.len() < 2 {
            continue;
        }
        let k_src = ktn.inputs[0];
        // K^T is [.., d_head, S]: keys on the last axis.
        let (dh, seq) = (ktn.shape[ktn.shape.len() - 2], ktn.shape[ktn.shape.len() - 1]);
        let bh: usize = ktn.shape[..ktn.shape.len() - 2].iter().product();
        if g.node(q).shape.last() != Some(&dh) || mm.shape.last() != Some(&seq) {
            continue;
        }
        // The context MatMul: consumes the softmax as its LHS.
        let av = users[s.id].iter().copied().find(|&u| {
            matches!(g.node(u).op, OpKind::MatMul) && g.node(u).inputs.first() == Some(&s.id)
        });
        let Some(av_mm) = av else { continue };
        let v_src = g.node(av_mm).inputs[1];
        if g.node(v_src).shape.last() != Some(&dh) {
            continue;
        }
        let mut chain = walked;
        chain.push(scores_mm);
        chain.reverse();
        specs.push(AttnSpec {
            scores_mm,
            kt,
            k_src,
            softmax: s.id,
            av_mm,
            v_src,
            chain,
            bh,
            dh,
            seq,
            causal,
        });
    }
    specs
}

/// Per-node execution plan of the incremental interpreter.
#[derive(Debug, Clone)]
enum Kind {
    /// The graph input: the current token id as f32.
    Token,
    /// Weight, read straight from the store.
    Weight,
    /// Weight-only subgraph evaluated once at construction.
    Const,
    /// Value never read during decode (the K^T transpose — the score
    /// kernel reads the cache instead).
    Skip,
    /// Token-id row lookup against a `[vocab, d]` table.
    Embedding { ids: NodeId, table: NodeId, vocab: usize, d: usize },
    /// Broadcast of a `[S, d]` table: row `p` at position `p` (learned
    /// position embeddings).
    PosRow { src: NodeId, d: usize },
    /// Broadcast of a 1-element value.
    ScalarBroadcast { src: NodeId },
    /// Row-vector GEMM against a `[in_f, out_f]` weight.
    Dense { x: NodeId, w: NodeId, in_f: usize, out_f: usize },
    Bias { x: NodeId, w: NodeId },
    LayerNorm { x: NodeId, w: NodeId, d: usize },
    /// Elementwise unary (Activation / Scale / Pow / Sqrt).
    Unary { x: NodeId },
    /// CausalMask on the newest query row: every cached key is allowed, so
    /// the mask is the identity during decode.
    MaskIdentity { x: NodeId },
    Binary { a: NodeId, b: NodeId },
    /// Row softmax; `row = None` means the dynamic key axis (current len).
    Softmax { x: NodeId, row: Option<usize> },
    /// `q × K_cacheᵀ` over the cached prefix.
    Scores { attn: usize, q: NodeId },
    /// `probs × V_cache` over the cached prefix.
    Av { attn: usize, probs: NodeId },
    /// Generic row MatMul against a constant rank-2 RHS (the LM head).
    RowMatMul { a: NodeId, b: NodeId, k: usize, n: usize },
    Transpose { x: NodeId, perm: Vec<usize> },
    /// Plain copy (Reshape / Flatten).
    Copy { x: NodeId },
}

#[derive(Debug, Clone)]
struct NodePlan {
    kind: Kind,
    /// Decode-time f32 elements; for `dynamic` nodes, elements *per cached
    /// position* (total = base × current length).
    base: usize,
    dynamic: bool,
    /// Append this node's value into attention `i`'s K (resp. V) cache.
    k_of: Option<usize>,
    v_of: Option<usize>,
}

/// One attention's per-session K/V cache: `[bh, max_seq, dh]` row-major,
/// appended in place, never reallocated.
#[derive(Debug)]
struct KvCache {
    k: Vec<f32>,
    v: Vec<f32>,
    bh: usize,
    dh: usize,
}

/// An autoregressive decoding session over a compiled causal decoder
/// graph. See the [module docs](self); constructed through
/// [`crate::api::CompiledModel::decode_session`].
pub struct DecodeSession<'m> {
    g: &'m Graph,
    plan: Vec<NodePlan>,
    /// Template decode shape per node (sequence dims substituted with 1).
    dshape: Vec<Vec<usize>>,
    /// Weight tensors resolved once (node id → store tensor).
    wref: Vec<Option<&'m Tensor>>,
    /// Constant-subgraph values evaluated once.
    consts: Vec<Option<Tensor>>,
    /// Per-node value buffers (sized for max_seq on dynamic nodes).
    bufs: Vec<Vec<f32>>,
    /// Input-dependent nodes in topological order.
    order: Vec<NodeId>,
    kv: Vec<KvCache>,
    out_id: NodeId,
    vocab: usize,
    max_seq: usize,
    /// Tokens consumed so far (the next step decodes position `len`).
    len: usize,
    /// Current sequence length *during* a step (`len + 1`).
    cur: usize,
    /// Every token consumed since the last `reset()`, in order
    /// (`history.len() == len`). Pre-allocated to `max_seq` so `step()`
    /// stays allocation-free; this is what [`DecodeSession::snapshot`]
    /// captures.
    history: Vec<u32>,
}

/// A checkpoint of a session's consumed-token history — the prompt plus
/// every generated token fed back so far. Deliberately tiny: it carries
/// *no* K/V state, so an evicted stream costs `4 × len` bytes to park
/// while its cache memory is reused. [`DecodeSession::restore`] rebuilds
/// the full K/V state by re-prefilling, which is bitwise-identical to
/// having never been evicted (prefill *is* N × `step()` — pinned by the
/// snapshot oracles in `tests/decode.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionSnapshot {
    tokens: Vec<u32>,
}

impl SessionSnapshot {
    /// The captured token history (prompt + generated), oldest first.
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    /// Number of positions the restored session will hold.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

impl<'m> DecodeSession<'m> {
    /// Build a session over a (rewritten) graph + weights. Errors loudly
    /// on anything that cannot decode incrementally: batch > 1, missing
    /// token embedding, non-causal attention, unsupported ops, or
    /// `max_seq` outside `1..=S`.
    pub fn new(g: &'m Graph, ws: &'m WeightStore, max_seq: usize) -> Result<DecodeSession<'m>> {
        DecodeSession::new_checked(g, ws, max_seq, cfg!(debug_assertions))
    }

    /// [`DecodeSession::new`] with the structural pre-check made explicit:
    /// the session API passes `check = true` whenever the model compiled
    /// with `.verify(true)`, so release builds keep the guarantee instead
    /// of silently dropping it (ISSUE-9 satellite). The trace-purity gate
    /// below runs unconditionally — it is cheap and a stateful op in the
    /// decode closure is always a hard error.
    pub fn new_checked(
        g: &'m Graph,
        ws: &'m WeightStore,
        max_seq: usize,
        check: bool,
    ) -> Result<DecodeSession<'m>> {
        let nn = g.nodes.len();
        // The decode planner trusts the graph invariants the IR verifier
        // proves (topological order, shape consistency, weight backing);
        // check them up front so a corrupted graph fails with a named
        // pass instead of a mid-plan index panic.
        if check {
            crate::verify::check_graph(g, Some(ws), "decode")?;
        }
        // --- the single token input ------------------------------------
        let inputs: Vec<NodeId> = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Input))
            .map(|n| n.id)
            .collect();
        let &[input_id] = &inputs[..] else {
            bail!("decode_session needs exactly one input node, got {}", inputs.len());
        };
        let ishape = &g.node(input_id).shape;
        if ishape.len() != 2 {
            bail!("decode_session needs a [batch, seq] token input, got {ishape:?}");
        }
        let (batch, seq) = (ishape[0], ishape[1]);
        if batch != 1 {
            bail!("decode_session supports batch 1 only (model compiled at batch {batch})");
        }
        if max_seq == 0 || max_seq > seq {
            bail!("max_seq {max_seq} outside the model's positional range 1..={seq}");
        }
        // The token input must feed an embedding row lookup — that is what
        // defines the vocabulary the session validates ids against.
        let vocab = g
            .nodes
            .iter()
            .find_map(|n| match n.op {
                OpKind::Embedding | OpKind::Gather
                    if n.inputs.len() == 2 && n.inputs[0] == input_id =>
                {
                    Some(g.node(n.inputs[1]).shape[0])
                }
                _ => None,
            })
            .ok_or_else(|| {
                anyhow!("decode_session needs the input consumed by a token embedding")
            })?;
        let &[out_id] = &g.outputs[..] else {
            bail!("decode_session needs exactly one graph output");
        };
        if !g.node(out_id).shape.contains(&seq) {
            bail!(
                "graph output {:?} has no sequence dim — not a per-position decoder head",
                g.node(out_id).shape
            );
        }

        // --- input-dependence closure ----------------------------------
        let mut dep = vec![false; nn];
        dep[input_id] = true;
        for n in &g.nodes {
            if !n.op.is_source() && n.inputs.iter().any(|&i| dep[i]) {
                dep[n.id] = true;
            }
        }
        if !dep[out_id] {
            bail!("graph output does not depend on the token input");
        }

        // --- trace-purity gate (ISSUE-9) -------------------------------
        // Every op the incremental trace replays per token must be pure:
        // a stateful op (detection post-processing) or a kernel-less
        // fallback op inside the decode closure would fail — or silently
        // corrupt — generation mid-stream. Reject it here, typed, with
        // the blamed node.
        for n in &g.nodes {
            if !dep[n.id] || n.op.is_source() {
                continue;
            }
            let eff = crate::analyze::op_effect(&n.op);
            if !eff.trace_safe() {
                return Err(XgenError::AnalysisDiagnostic {
                    code: "trace-unsafe".to_string(),
                    node: n.id,
                    name: n.name.clone(),
                    detail: format!(
                        "op '{}' is {} — the incremental decode trace cannot replay it",
                        n.op.name(),
                        eff.name()
                    ),
                }
                .into());
            }
        }

        // --- constant subgraphs, evaluated once ------------------------
        let mut wref: Vec<Option<&'m Tensor>> = vec![None; nn];
        let mut consts: Vec<Option<Tensor>> = vec![None; nn];
        for n in &g.nodes {
            if dep[n.id] {
                continue;
            }
            match n.op {
                OpKind::Weight => {
                    wref[n.id] = Some(
                        ws.get(&n.name)
                            .ok_or_else(|| anyhow!("weight '{}' missing", n.name))?,
                    );
                }
                OpKind::Input => {}
                _ => {
                    let args: Vec<&Tensor> = n
                        .inputs
                        .iter()
                        .map(|&i| {
                            consts[i]
                                .as_ref()
                                .or(wref[i])
                                .ok_or_else(|| anyhow!("constant input {i} unavailable"))
                        })
                        .collect::<Result<_>>()?;
                    consts[n.id] = Some(eval_op(g, n.id, &args)?);
                }
            }
        }

        // --- attention discovery + K/V caches --------------------------
        let specs: Vec<AttnSpec> = attention_specs(g);
        for a in &specs {
            if !a.causal {
                bail!(
                    "attention at node {} is not causal — incremental decoding would \
                     change its semantics (build the model with causal attention)",
                    a.softmax
                );
            }
        }
        let mut in_chain = vec![false; nn];
        let mut k_of = vec![None; nn];
        let mut v_of = vec![None; nn];
        let mut skip = vec![false; nn];
        let users = g.users();
        for (ai, a) in specs.iter().enumerate() {
            for &c in &a.chain {
                in_chain[c] = true;
            }
            k_of[a.k_src] = Some(ai);
            v_of[a.v_src] = Some(ai);
            // The K^T value itself is never read — the score kernel runs
            // against the cache — unless something else consumes it.
            if users[a.kt].len() == 1 && users[a.kt][0] == a.scores_mm {
                skip[a.kt] = true;
            }
        }
        // Any score-shaped softmax the detector did not claim would decode
        // incorrectly — refuse instead.
        for n in &g.nodes {
            if matches!(n.op, OpKind::Softmax) && dep[n.id] && !in_chain[n.id] {
                let sh = &n.shape;
                if sh.len() >= 2 && sh[sh.len() - 1] == seq && sh[sh.len() - 2] == seq {
                    bail!("unrecognized attention structure at softmax node {}", n.id);
                }
            }
        }

        // --- decode-time shapes (seq → 1 substitution) ------------------
        let sub = |shape: &[usize]| -> Vec<usize> {
            shape.iter().map(|&d| if d == seq { 1 } else { d }).collect()
        };
        let mut dshape: Vec<Vec<usize>> = vec![Vec::new(); nn];
        for n in &g.nodes {
            dshape[n.id] = sub(&n.shape);
        }

        // --- per-node plans ---------------------------------------------
        let data_and_weight = |id: NodeId| -> Result<(NodeId, NodeId)> {
            super::split_data_weight(g, id)
        };
        let mut plan: Vec<NodePlan> = Vec::with_capacity(nn);
        for n in &g.nodes {
            let id = n.id;
            let dynamic = in_chain[id];
            let base = if dynamic {
                // Chain tensors are [.., 1, keys]: elements per key.
                let sh = &g.node(id).shape;
                sub(&sh[..sh.len() - 1]).iter().product()
            } else {
                dshape[id].iter().product()
            };
            let kind = if skip[id] {
                Kind::Skip
            } else if !dep[id] {
                match &n.op {
                    OpKind::Weight => Kind::Weight,
                    // Constant broadcasts must stay *per-step* kernels, not
                    // materialized full-sequence tensors: the position
                    // table contributes row `p` at position `p`, and a
                    // scalar (the sqrt(d_k) divisor) stays one element so
                    // decode-time elementwise consumers re-broadcast it.
                    OpKind::Broadcast => {
                        let src = n.inputs[0];
                        let ss = &g.node(src).shape;
                        if ss.iter().product::<usize>() == 1 {
                            Kind::ScalarBroadcast { src }
                        } else if ss.len() == 2 && ss[0] == seq && n.shape[..] == [1, seq, ss[1]]
                        {
                            Kind::PosRow { src, d: ss[1] }
                        } else {
                            Kind::Const
                        }
                    }
                    _ => Kind::Const,
                }
            } else {
                match &n.op {
                    OpKind::Input => Kind::Token,
                    OpKind::Embedding | OpKind::Gather => {
                        if n.inputs.len() != 2 {
                            bail!("decode supports only the row-lookup embedding form");
                        }
                        let ts = &g.node(n.inputs[1]).shape;
                        Kind::Embedding {
                            ids: n.inputs[0],
                            table: n.inputs[1],
                            vocab: ts[0],
                            d: ts[1],
                        }
                    }
                    OpKind::Broadcast => {
                        let src = n.inputs[0];
                        let ss = &g.node(src).shape;
                        if ss.iter().product::<usize>() == 1 {
                            Kind::ScalarBroadcast { src }
                        } else if !dep[src]
                            && ss.len() == 2
                            && ss[0] == seq
                            && n.shape[..] == [1, seq, ss[1]]
                        {
                            Kind::PosRow { src, d: ss[1] }
                        } else {
                            bail!("decode cannot broadcast {:?} -> {:?}", ss, n.shape);
                        }
                    }
                    OpKind::Dense => {
                        let (x, w) = data_and_weight(id)?;
                        let wsh = &g.node(w).shape;
                        Kind::Dense { x, w, in_f: wsh[0], out_f: wsh[1] }
                    }
                    OpKind::Bias => {
                        let (x, w) = data_and_weight(id)?;
                        Kind::Bias { x, w }
                    }
                    OpKind::LayerNorm => {
                        let (x, w) = data_and_weight(id)?;
                        Kind::LayerNorm { x, w, d: g.node(w).shape[1] }
                    }
                    OpKind::Activation(_) | OpKind::Pow { .. } | OpKind::Sqrt => {
                        Kind::Unary { x: n.inputs[0] }
                    }
                    OpKind::Scale { .. } if n.inputs.len() == 1 => Kind::Unary { x: n.inputs[0] },
                    OpKind::CausalMask => Kind::MaskIdentity { x: n.inputs[0] },
                    OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div => {
                        Kind::Binary { a: n.inputs[0], b: n.inputs[1] }
                    }
                    OpKind::Softmax => Kind::Softmax {
                        x: n.inputs[0],
                        row: if dynamic { None } else { Some(*dshape[id].last().unwrap()) },
                    },
                    OpKind::MatMul => {
                        if let Some(ai) = specs.iter().position(|a| a.scores_mm == id) {
                            Kind::Scores { attn: ai, q: n.inputs[0] }
                        } else if let Some(ai) = specs.iter().position(|a| a.av_mm == id) {
                            Kind::Av { attn: ai, probs: n.inputs[0] }
                        } else {
                            let b = n.inputs[1];
                            if dep[b] || dshape[b].len() != 2 {
                                bail!(
                                    "decode MatMul at node {id} needs a constant rank-2 RHS \
                                     (got {:?})",
                                    g.node(b).shape
                                );
                            }
                            Kind::RowMatMul {
                                a: n.inputs[0],
                                b,
                                k: dshape[b][0],
                                n: dshape[b][1],
                            }
                        }
                    }
                    OpKind::Transpose { perm } => {
                        Kind::Transpose { x: n.inputs[0], perm: perm.clone() }
                    }
                    OpKind::Reshape | OpKind::Flatten => Kind::Copy { x: n.inputs[0] },
                    other => bail!(
                        "op '{}' (node {id}) is not supported by the incremental decoder",
                        other.name()
                    ),
                }
            };
            // A scalar broadcast materializes one element regardless of its
            // baked full-sequence shape — consumers broadcast it back out.
            let base = if matches!(kind, Kind::ScalarBroadcast { .. }) { 1 } else { base };
            plan.push(NodePlan { kind, base, dynamic, k_of: k_of[id], v_of: v_of[id] });
        }

        // Structural sanity: cached rows and the score/context operands
        // must agree on the bh×dh layout.
        for a in &specs {
            for src in [a.k_src, a.v_src] {
                if plan[src].dynamic || plan[src].base != a.row_elems() {
                    bail!(
                        "attention K/V producer {src} yields {} elements per step, \
                         expected {}×{}",
                        plan[src].base,
                        a.bh,
                        a.dh
                    );
                }
            }
            let q = g.node(a.scores_mm).inputs[0];
            if plan[q].base != a.row_elems() {
                bail!("attention Q producer {q} does not match bh×dh");
            }
            if plan[a.av_mm].base != a.row_elems() {
                bail!("attention context {0} does not match bh×dh", a.av_mm);
            }
        }
        // Copy-kind (reshape) element counts must survive substitution.
        for n in &g.nodes {
            if let Kind::Copy { x } = &plan[n.id].kind {
                if plan[n.id].dynamic != plan[*x].dynamic || plan[n.id].base != plan[*x].base {
                    bail!("reshape at node {} changes decode element count", n.id);
                }
            }
        }

        // Constant broadcasts re-kinded to per-step kernels: drop their
        // materialized full-sequence values so `read` resolves to the
        // per-step buffer, not the stale constant.
        for (id, p) in plan.iter().enumerate() {
            if matches!(p.kind, Kind::PosRow { .. } | Kind::ScalarBroadcast { .. }) {
                consts[id] = None;
            }
        }

        let evaluated =
            |k: &Kind| !matches!(k, Kind::Weight | Kind::Const | Kind::Skip);
        let bufs: Vec<Vec<f32>> = plan
            .iter()
            .map(|p| {
                if !evaluated(&p.kind) {
                    Vec::new()
                } else if p.dynamic {
                    vec![0.0; p.base * max_seq]
                } else {
                    vec![0.0; p.base]
                }
            })
            .collect();
        let kv = specs
            .iter()
            .map(|a| KvCache {
                k: vec![0.0; a.row_elems() * max_seq],
                v: vec![0.0; a.row_elems() * max_seq],
                bh: a.bh,
                dh: a.dh,
            })
            .collect();
        let order: Vec<NodeId> = (0..nn).filter(|&id| evaluated(&plan[id].kind)).collect();
        Ok(DecodeSession {
            g,
            plan,
            dshape,
            wref,
            consts,
            bufs,
            order,
            kv,
            out_id,
            vocab,
            max_seq,
            len: 0,
            cur: 0,
            history: Vec::with_capacity(max_seq),
        })
    }

    /// Maximum positions this session can hold.
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Tokens consumed so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Vocabulary size token ids are validated against.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Total K/V cache elements held by this session
    /// (`Σ attentions 2 × bh × d_head × max_seq` — the planner's
    /// [`WorkspaceSpec::kv_cache_elems`](super::planner::WorkspaceSpec::kv_cache_elems)
    /// sizing).
    pub fn kv_cache_elems(&self) -> usize {
        self.kv.iter().map(|c| c.k.len() + c.v.len()).sum()
    }

    /// Rewind to an empty sequence so the session (and its caches) can be
    /// reused without reallocation.
    pub fn reset(&mut self) {
        self.len = 0;
        self.history.clear();
    }

    /// Every token consumed since the last `reset()`, oldest first.
    pub fn tokens(&self) -> &[u32] {
        &self.history
    }

    /// Checkpoint the session as its token history alone. The K/V caches
    /// are *not* copied — [`restore`](DecodeSession::restore) re-derives
    /// them by re-prefilling, so a snapshot is cheap enough to take on
    /// every eviction under memory pressure.
    pub fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot { tokens: self.history.clone() }
    }

    /// Replace this session's state with a [`SessionSnapshot`]: reset,
    /// then re-prefill the captured history. Continuation afterwards
    /// (`step`, `generate_continue`) is bitwise-identical to a session
    /// that was never snapshotted, on *any* session of the same model —
    /// including a freshly built one. An empty snapshot restores to the
    /// reset state. On `Err` (snapshot longer than `max_seq`, id out of
    /// vocabulary) the session is left reset and empty.
    pub fn restore(&mut self, snap: &SessionSnapshot) -> Result<()> {
        self.reset();
        if snap.tokens.is_empty() {
            return Ok(());
        }
        self.prefill(&snap.tokens)?;
        Ok(())
    }

    /// Feed a prompt, one position at a time; returns the logits row of
    /// the *last* prompt token.
    pub fn prefill(&mut self, tokens: &[u32]) -> Result<&[f32]> {
        if tokens.is_empty() {
            bail!("prefill needs at least one token");
        }
        if self.len + tokens.len() > self.max_seq {
            return Err(XgenError::SeqOverflow {
                at: self.len,
                want: tokens.len(),
                max_seq: self.max_seq,
            }
            .into());
        }
        // Validate every id up front so prefill is atomic: a bad token
        // mid-prompt must not leave the session partially advanced.
        for &t in tokens {
            if t as usize >= self.vocab {
                return Err(XgenError::VocabOutOfRange { token: t, vocab: self.vocab }.into());
            }
        }
        for &t in tokens {
            self.advance(t)?;
        }
        Ok(self.logits())
    }

    /// Decode one token: appends its K/V rows to the caches and returns
    /// the logits row for the next position. Allocation-free after
    /// warm-up; loud errors on out-of-range ids and full sequences.
    pub fn step(&mut self, token: u32) -> Result<&[f32]> {
        #[cfg(feature = "fault-injection")]
        crate::runtime::fault::on_decode_step();
        self.advance(token)?;
        Ok(self.logits())
    }

    /// Greedy decoding convenience: prefill the prompt, then emit `n`
    /// argmax tokens.
    pub fn generate(&mut self, prompt: &[u32], n: usize) -> Result<Vec<u32>> {
        self.prefill(prompt)?;
        self.generate_continue(n)
    }

    /// Continue greedy decoding from the current position: emit `n` argmax
    /// tokens starting from the logits of the last decoded position
    /// (requires a prior `prefill`/`step`).
    pub fn generate_continue(&mut self, n: usize) -> Result<Vec<u32>> {
        if self.len == 0 {
            bail!("generate_continue needs a prefilled prompt");
        }
        let mut logits = self.logits().to_vec();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let next = argmax(&logits) as u32;
            out.push(next);
            if i + 1 < n {
                logits.clear();
                logits.extend_from_slice(self.step(next)?);
            }
        }
        Ok(out)
    }

    /// The logits row of the most recently decoded position.
    fn logits(&self) -> &[f32] {
        &self.bufs[self.out_id][..self.plan[self.out_id].base]
    }

    /// Run one position through the interpreter.
    /// On `Err` the session is untouched: `len` does not advance and the
    /// K/V caches keep their pre-call lengths, so callers can recover with
    /// a corrected token (or `reset()`) — pinned by the error-then-continue
    /// oracle in `tests/robustness.rs`.
    fn advance(&mut self, token: u32) -> Result<()> {
        if self.len >= self.max_seq {
            return Err(XgenError::SeqOverflow {
                at: self.len,
                want: 1,
                max_seq: self.max_seq,
            }
            .into());
        }
        if token as usize >= self.vocab {
            return Err(XgenError::VocabOutOfRange { token, vocab: self.vocab }.into());
        }
        let p = self.len;
        self.cur = p + 1;
        for oi in 0..self.order.len() {
            let id = self.order[oi];
            let elems = self.len_of(id);
            // Take the output buffer out so sibling buffers stay readable.
            let mut ob = std::mem::take(&mut self.bufs[id]);
            let res = self.eval_node(id, token, &mut ob[..elems]);
            #[cfg(feature = "fault-injection")]
            let res = res.and_then(|()| {
                crate::runtime::fault::on_decode_node(&self.g.node(id).name, &mut ob[..elems])
                    .map_err(|m| anyhow::anyhow!(m))
            });
            if res.is_ok() {
                let max_seq = self.max_seq;
                if let Some(ai) = self.plan[id].k_of {
                    let c = &mut self.kv[ai];
                    append_rows(&mut c.k, c.bh, c.dh, max_seq, p, &ob);
                }
                if let Some(ai) = self.plan[id].v_of {
                    let c = &mut self.kv[ai];
                    append_rows(&mut c.v, c.bh, c.dh, max_seq, p, &ob);
                }
            }
            self.bufs[id] = ob;
            res?;
        }
        self.len = p + 1;
        self.history.push(token);
        Ok(())
    }

    /// Decode-time element count of a node's current value.
    fn len_of(&self, id: NodeId) -> usize {
        let pl = &self.plan[id];
        if pl.dynamic {
            pl.base * self.cur
        } else {
            pl.base
        }
    }

    /// Read a node's current value (weight / precomputed constant /
    /// per-step buffer).
    fn read(&self, id: NodeId) -> &[f32] {
        if let Some(t) = self.wref[id] {
            return t.data();
        }
        if let Some(t) = &self.consts[id] {
            return t.data();
        }
        &self.bufs[id][..self.len_of(id)]
    }

    fn eval_node(&self, id: NodeId, token: u32, out: &mut [f32]) -> Result<()> {
        let cur = self.cur;
        match &self.plan[id].kind {
            Kind::Token => {
                out[0] = token as f32;
                Ok(())
            }
            Kind::Embedding { ids, table, vocab, d } => {
                embedding_into(self.read(*ids), self.read(*table), *vocab, *d, out)
            }
            Kind::PosRow { src, d } => {
                let (p, d) = (cur - 1, *d);
                out.copy_from_slice(&self.read(*src)[p * d..(p + 1) * d]);
                Ok(())
            }
            Kind::ScalarBroadcast { src } => {
                out[0] = self.read(*src)[0];
                Ok(())
            }
            Kind::Dense { x, w, in_f, out_f } => {
                row_matmul(self.read(*x), self.read(*w), *in_f, *out_f, out);
                Ok(())
            }
            Kind::RowMatMul { a, b, k, n } => {
                row_matmul(self.read(*a), self.read(*b), *k, *n, out);
                Ok(())
            }
            Kind::Bias { x, w } => {
                let xv = self.read(*x);
                let wv = self.read(*w);
                let c = wv.len();
                let per = per_channel_stride(&self.dshape[*x], c).0;
                for (i, v) in out.iter_mut().enumerate() {
                    *v = xv[i] + wv[(i / per) % c];
                }
                Ok(())
            }
            Kind::LayerNorm { x, w, d } => {
                let xv = self.read(*x);
                let wv = self.read(*w);
                let d = *d;
                out.copy_from_slice(&xv[..out.len()]);
                for row in out.chunks_exact_mut(d) {
                    let mean: f32 = row.iter().sum::<f32>() / d as f32;
                    let var: f32 =
                        row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
                    let inv = 1.0 / (var + 1e-5).sqrt();
                    for (i, v) in row.iter_mut().enumerate() {
                        *v = (*v - mean) * inv * wv[i] + wv[d + i];
                    }
                }
                Ok(())
            }
            Kind::Unary { x } => {
                let xv = self.read(*x);
                out.copy_from_slice(&xv[..out.len()]);
                apply_unary_slice_inplace(&self.g.node(id).op, out);
                Ok(())
            }
            Kind::MaskIdentity { x } => {
                // The newest query row attends to every cached position —
                // the causal mask is the identity on the decode path.
                out.copy_from_slice(&self.read(*x)[..out.len()]);
                Ok(())
            }
            Kind::Binary { a, b } => {
                let av = self.read(*a);
                let bv = self.read(*b);
                let op = &self.g.node(id).op;
                if av.len() == out.len() && bv.len() == out.len() {
                    for (i, v) in out.iter_mut().enumerate() {
                        *v = binop(op, av[i], bv[i]);
                    }
                } else if bv.len() == 1 && av.len() == out.len() {
                    let s = bv[0];
                    for (i, v) in out.iter_mut().enumerate() {
                        *v = binop(op, av[i], s);
                    }
                } else if av.len() == 1 && bv.len() == out.len() {
                    let s = av[0];
                    for (i, v) in out.iter_mut().enumerate() {
                        *v = binop(op, s, bv[i]);
                    }
                } else {
                    bail!(
                        "decode elementwise shape mismatch at node {id}: {} vs {} -> {}",
                        av.len(),
                        bv.len(),
                        out.len()
                    );
                }
                Ok(())
            }
            Kind::Softmax { x, row } => {
                let l = (*row).unwrap_or(cur);
                out.copy_from_slice(&self.read(*x)[..out.len()]);
                softmax_rows_inplace(out, l);
                Ok(())
            }
            Kind::Scores { attn, q } => {
                let qv = self.read(*q);
                let c = &self.kv[*attn];
                for b in 0..c.bh {
                    let qrow = &qv[b * c.dh..(b + 1) * c.dh];
                    for j in 0..cur {
                        let krow = &c.k[(b * self.max_seq + j) * c.dh..][..c.dh];
                        let mut acc = 0.0f32;
                        for (a, b2) in qrow.iter().zip(krow) {
                            acc += a * b2;
                        }
                        out[b * cur + j] = acc;
                    }
                }
                Ok(())
            }
            Kind::Av { attn, probs } => {
                let pv = self.read(*probs);
                let c = &self.kv[*attn];
                for b in 0..c.bh {
                    let orow = &mut out[b * c.dh..(b + 1) * c.dh];
                    orow.fill(0.0);
                    for j in 0..cur {
                        let pj = pv[b * cur + j];
                        let vrow = &c.v[(b * self.max_seq + j) * c.dh..][..c.dh];
                        for (o, vv) in orow.iter_mut().zip(vrow) {
                            *o += pj * vv;
                        }
                    }
                }
                Ok(())
            }
            Kind::Transpose { x, perm } => {
                transpose_into(self.read(*x), &self.dshape[*x], perm, out);
                Ok(())
            }
            Kind::Copy { x } => {
                out.copy_from_slice(&self.read(*x)[..out.len()]);
                Ok(())
            }
            Kind::Weight | Kind::Const | Kind::Skip => Ok(()),
        }
    }
}

/// `out[r, j] = Σ_i x[r, i] · w[i, j]` over a row-major `[in_f, out_f]`
/// RHS — axpy order so the weight streams row-contiguously. The decoder's
/// row GEMM: allocation-free, no panel packing (rows is 1 on the hot
/// path, so blocked packing would cost more than it saves).
fn row_matmul(x: &[f32], w: &[f32], in_f: usize, out_f: usize, out: &mut [f32]) {
    let rows = out.len() / out_f;
    out.fill(0.0);
    for r in 0..rows {
        let xrow = &x[r * in_f..(r + 1) * in_f];
        let orow = &mut out[r * out_f..(r + 1) * out_f];
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * out_f..(i + 1) * out_f];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

fn binop(op: &OpKind, a: f32, b: f32) -> f32 {
    match op {
        OpKind::Add => a + b,
        OpKind::Sub => a - b,
        OpKind::Mul => a * b,
        _ => a / b,
    }
}

/// Append one `[bh, dh]` row block into a `[bh, max_seq, dh]` cache at
/// position `p`.
fn append_rows(cache: &mut [f32], bh: usize, dh: usize, max_seq: usize, p: usize, row: &[f32]) {
    for b in 0..bh {
        cache[(b * max_seq + p) * dh..(b * max_seq + p + 1) * dh]
            .copy_from_slice(&row[b * dh..(b + 1) * dh]);
    }
}

/// Index of the largest logit (NaN-safe via total order; first wins ties)
/// — the greedy sampling rule `generate` and the token-streaming server
/// share.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, v) in xs.iter().enumerate() {
        if v.total_cmp(&xs[best]) == std::cmp::Ordering::Greater {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo::nlp;
    use crate::graph::WeightStore;
    use crate::util::rng::Rng;

    #[test]
    fn detector_finds_causal_attention_in_both_forms() {
        // Compact form: 2 layers → 2 specs, bh = batch, dh = d.
        let g = nlp::demo_transformer_causal(1);
        let specs = attention_specs(&g);
        assert_eq!(specs.len(), 2);
        for a in &specs {
            assert!(a.causal);
            assert_eq!((a.bh, a.dh, a.seq), (1, 64, 32));
            assert!(a.chain.len() >= 3, "scores→scale→mask→softmax");
            assert_eq!(a.chain[0], a.scores_mm);
            assert_eq!(*a.chain.last().unwrap(), a.softmax);
        }
        // Frontend form: per-head rank-4 shapes, bh = heads.
        let g = nlp::gpt2_frontend_layers(1, 2);
        let specs = attention_specs(&g);
        assert_eq!(specs.len(), 2);
        for a in &specs {
            assert!(a.causal);
            assert_eq!((a.bh, a.dh, a.seq), (12, 64, 384));
        }
        // Encoder form: detected but not causal.
        let g = nlp::demo_transformer(1);
        let specs = attention_specs(&g);
        assert_eq!(specs.len(), 2);
        assert!(specs.iter().all(|a| !a.causal));
        // No attention at all.
        assert!(attention_specs(&crate::graph::zoo::by_name("demo-cnn", 1)).is_empty());
    }

    #[test]
    fn session_rejects_non_causal_and_non_decoder_models() {
        let mut rng = Rng::new(3);
        let g = nlp::demo_transformer(1);
        let ws = WeightStore::init_random(&g, &mut rng);
        let err = DecodeSession::new(&g, &ws, 8).unwrap_err().to_string();
        assert!(err.contains("not causal"), "got: {err}");

        let g = crate::graph::zoo::by_name("demo-cnn", 1);
        let ws = WeightStore::init_random(&g, &mut rng);
        assert!(DecodeSession::new(&g, &ws, 8).is_err());
    }

    #[test]
    fn session_validates_tokens_and_length() {
        let mut rng = Rng::new(4);
        let g = nlp::demo_transformer_causal(1);
        let ws = WeightStore::init_random(&g, &mut rng);
        // max_seq outside the positional range.
        assert!(DecodeSession::new(&g, &ws, 0).is_err());
        assert!(DecodeSession::new(&g, &ws, 33).is_err());
        let mut s = DecodeSession::new(&g, &ws, 4).unwrap();
        assert_eq!(s.vocab(), 256);
        // Out-of-range token: loud error, not the executor's bounds panic.
        let err = s.step(256).unwrap_err().to_string();
        assert!(err.contains("out of range"), "got: {err}");
        assert_eq!(s.len(), 0, "failed step must not advance");
        // Too-long prompt.
        let err = s.prefill(&[1, 2, 3, 4, 5]).unwrap_err().to_string();
        assert!(err.contains("exceeds max_seq"), "got: {err}");
        // Fill up, then overflow.
        s.prefill(&[1, 2, 3, 4]).unwrap();
        let err = s.step(1).unwrap_err().to_string();
        assert!(err.contains("full"), "got: {err}");
        // reset() rewinds without reallocation.
        s.reset();
        assert!(s.is_empty());
        s.prefill(&[9, 8]).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.kv_cache_elems() > 0);
        // prefill is atomic: a bad id mid-prompt advances nothing.
        let err = s.prefill(&[1, 300]).unwrap_err().to_string();
        assert!(err.contains("out of range"), "got: {err}");
        assert_eq!(s.len(), 2, "failed prefill must not advance");
    }
}
