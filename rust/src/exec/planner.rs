//! Executor memory planner: a liveness pass over the graph IR that assigns
//! every **materialized** compute value to a slot in a small pool of
//! reusable buffers, instead of one tensor per node.
//!
//! The plan is computed against an explicit **execution order** (the
//! straight-line node order, or the flattened fused-group order of a
//! [`crate::fusion::FusionPlan`]) plus a `materialize` mask saying which
//! values are actually stored (for the fused executor: group tails and
//! members whose value escapes their group — intra-group intermediates
//! live only in the running buffer and need no slot). A value is live
//! from its definition position to the position of its last consumer
//! (graph outputs are live forever). Two values may share a slot iff
//! their live ranges are disjoint, which the greedy first-free
//! assignment below guarantees.
//!
//! [`PlanStats`] quantifies the win — `slots` vs `planned_values` is the
//! peak-live-allocation reduction the acceptance bench reports, and the
//! byte counters compare pooled high-water memory against the
//! one-buffer-per-value baseline.

use crate::graph::{Graph, NodeId};

/// Size statistics of a memory plan.
#[derive(Debug, Clone, Default)]
pub struct PlanStats {
    /// Materialized values planned (one buffer each without pooling).
    pub planned_values: usize,
    /// Buffer slots actually needed.
    pub slots: usize,
    /// Maximum number of simultaneously live values.
    pub peak_live: usize,
    /// Bytes if every planned value kept its own buffer for the whole run.
    pub bytes_one_per_node: u64,
    /// High-water bytes of the pooled slots (each slot sized to the largest
    /// tensor it ever holds).
    pub bytes_pooled: u64,
}

impl PlanStats {
    /// Fraction of buffer bytes eliminated by pooling.
    pub fn bytes_saved_frac(&self) -> f64 {
        if self.bytes_one_per_node == 0 {
            return 0.0;
        }
        1.0 - self.bytes_pooled as f64 / self.bytes_one_per_node as f64
    }
}

/// A buffer-slot assignment for one graph under one execution order.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// node id -> slot index (None for sources and values that never
    /// materialize).
    pub slot_of: Vec<Option<usize>>,
    /// Number of slots in the pool.
    pub num_slots: usize,
    /// expire[p] = planned values that die right after executing position
    /// `p` (their slot may be reused from position `p+1` on). Graph
    /// outputs never appear here.
    pub expire: Vec<Vec<NodeId>>,
    pub stats: PlanStats,
}

impl MemoryPlan {
    /// Plan buffers for executing `g`'s compute nodes in `order`; only
    /// nodes with `materialize[id]` are given slots (every element of
    /// `order` must be a compute node id; sources are read from their own
    /// storage and never planned).
    pub fn new(g: &Graph, order: &[NodeId], materialize: &[bool]) -> MemoryPlan {
        let nn = g.nodes.len();
        let mut pos = vec![usize::MAX; nn];
        for (p, &id) in order.iter().enumerate() {
            debug_assert!(!g.node(id).op.is_source(), "sources are not planned");
            pos[id] = p;
        }
        // Last-use position per ordered node; usize::MAX = live forever.
        let mut last = vec![0usize; nn];
        for &id in order {
            last[id] = pos[id];
        }
        for &id in order {
            for &i in &g.node(id).inputs {
                if pos[i] != usize::MAX && last[i] != usize::MAX {
                    last[i] = last[i].max(pos[id]);
                }
            }
        }
        for &o in &g.outputs {
            if pos[o] != usize::MAX {
                last[o] = usize::MAX;
            }
        }

        let mut slot_of: Vec<Option<usize>> = vec![None; nn];
        let mut slot_bytes: Vec<u64> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        let mut expire: Vec<Vec<NodeId>> = vec![Vec::new(); order.len()];
        let mut live = 0usize;
        let mut peak = 0usize;
        let mut planned_values = 0usize;
        let mut bytes_one = 0u64;
        for (p, &id) in order.iter().enumerate() {
            if materialize[id] {
                let bytes = g.node(id).out_elems() * 4;
                bytes_one += bytes;
                planned_values += 1;
                let s = match free.pop() {
                    Some(s) => s,
                    None => {
                        slot_bytes.push(0);
                        slot_bytes.len() - 1
                    }
                };
                slot_of[id] = Some(s);
                slot_bytes[s] = slot_bytes[s].max(bytes);
                live += 1;
                peak = peak.max(live);
            }
            // Release every distinct planned value whose last use is this
            // position.
            let ins = &g.node(id).inputs;
            for (ii, &i) in ins.iter().enumerate() {
                if ins[..ii].contains(&i) {
                    continue;
                }
                if pos[i] != usize::MAX && last[i] == p {
                    if let Some(si) = slot_of[i] {
                        free.push(si);
                        expire[p].push(i);
                        live -= 1;
                    }
                }
            }
            // A planned value nobody consumes (and that is not an output)
            // dies at its own definition.
            if last[id] == p {
                if let Some(s) = slot_of[id] {
                    free.push(s);
                    expire[p].push(id);
                    live -= 1;
                }
            }
        }

        let stats = PlanStats {
            planned_values,
            slots: slot_bytes.len(),
            peak_live: peak,
            bytes_one_per_node: bytes_one,
            bytes_pooled: slot_bytes.iter().sum(),
        };
        MemoryPlan { slot_of, num_slots: slot_bytes.len(), expire, stats }
    }

    /// Plan for the straight-line (node-id) execution order, where every
    /// compute value materializes.
    pub fn straight_line(g: &Graph) -> MemoryPlan {
        let order: Vec<NodeId> = g.compute_nodes();
        let materialize = vec![true; g.nodes.len()];
        MemoryPlan::new(g, &order, &materialize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo::NetBuilder;
    use crate::graph::Act;

    fn chain_cnn() -> Graph {
        let mut b = NetBuilder::new("chain", &[1, 3, 16, 16]);
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        let skip = b.cur();
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        let t = b.cur();
        b.add_residual(skip, t);
        b.gap();
        b.dense(10);
        b.finish()
    }

    #[test]
    fn pool_is_much_smaller_than_one_per_node() {
        let g = chain_cnn();
        let plan = MemoryPlan::straight_line(&g);
        assert_eq!(plan.stats.planned_values, g.compute_nodes().len());
        assert!(
            plan.stats.slots * 2 < plan.stats.planned_values,
            "slots {} vs values {}",
            plan.stats.slots,
            plan.stats.planned_values
        );
        assert!(plan.stats.peak_live <= plan.stats.slots);
        assert!(plan.stats.bytes_pooled < plan.stats.bytes_one_per_node);
        assert!(plan.stats.bytes_saved_frac() > 0.5);
    }

    #[test]
    fn shared_slots_have_disjoint_live_ranges() {
        let g = chain_cnn();
        let order = g.compute_nodes();
        let materialize = vec![true; g.nodes.len()];
        let plan = MemoryPlan::new(&g, &order, &materialize);
        // Replay: walk the order; a slot must never be written while the
        // previous occupant is still live.
        let mut occupant: Vec<Option<NodeId>> = vec![None; plan.num_slots];
        let mut dead = vec![false; g.nodes.len()];
        for (p, &id) in order.iter().enumerate() {
            let s = plan.slot_of[id].unwrap();
            if let Some(prev) = occupant[s] {
                assert!(dead[prev], "slot {s} reused while node {prev} lives");
            }
            occupant[s] = Some(id);
            for &d in &plan.expire[p] {
                dead[d] = true;
            }
        }
        // Outputs never expire.
        for &o in &g.outputs {
            assert!(!dead[o], "output {o} was expired");
        }
    }

    #[test]
    fn unmaterialized_values_get_no_slot() {
        let g = chain_cnn();
        let order = g.compute_nodes();
        // Materialize only every third value (plus the output).
        let mut materialize = vec![false; g.nodes.len()];
        for (i, &id) in order.iter().enumerate() {
            if i % 3 == 0 {
                materialize[id] = true;
            }
        }
        for &o in &g.outputs {
            materialize[o] = true;
        }
        let plan = MemoryPlan::new(&g, &order, &materialize);
        for &id in &order {
            assert_eq!(plan.slot_of[id].is_some(), materialize[id], "node {id}");
        }
        let planned = order.iter().filter(|&&id| materialize[id]).count();
        assert_eq!(plan.stats.planned_values, planned);
        assert!(plan.stats.slots <= planned);
        // Expire lists contain only planned values.
        for evs in &plan.expire {
            for &d in evs {
                assert!(materialize[d]);
            }
        }
    }

    #[test]
    fn outputs_keep_their_slot_forever() {
        let g = chain_cnn();
        let plan = MemoryPlan::straight_line(&g);
        let out = g.outputs[0];
        for evs in &plan.expire {
            assert!(!evs.contains(&out));
        }
    }
}
