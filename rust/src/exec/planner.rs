//! Executor memory planner: a liveness pass over the graph IR that assigns
//! every **materialized** compute value to a slot in a small pool of
//! reusable buffers, instead of one tensor per node.
//!
//! The plan is computed against an explicit **execution order** (the
//! straight-line node order, or the flattened fused-group order of a
//! [`crate::fusion::FusionPlan`]) plus a `materialize` mask saying which
//! values are actually stored (for the fused executor: group tails and
//! members whose value escapes their group — intra-group intermediates
//! live only in the running buffer and need no slot). A value is live
//! from its definition position to the position of its last consumer
//! (graph outputs are live forever). Two values may share a slot iff
//! their live ranges are disjoint, which the greedy first-free
//! assignment below guarantees.
//!
//! [`PlanStats`] quantifies the win — `slots` vs `planned_values` is the
//! peak-live-allocation reduction the acceptance bench reports, and the
//! byte counters compare pooled high-water memory against the
//! one-buffer-per-value baseline.

use crate::graph::{Graph, NodeId, OpKind};
use crate::tensor::gemm::{prepacked_scratch_elems, GemmConfig};
use crate::tensor::qgemm::qgemm_scratch_band_bytes;

/// Size statistics of a memory plan.
#[derive(Debug, Clone, Default)]
pub struct PlanStats {
    /// Materialized values planned (one buffer each without pooling).
    pub planned_values: usize,
    /// Buffer slots actually needed.
    pub slots: usize,
    /// Maximum number of simultaneously live values.
    pub peak_live: usize,
    /// Bytes if every planned value kept its own buffer for the whole run.
    pub bytes_one_per_node: u64,
    /// High-water bytes of the pooled slots (each slot sized to the largest
    /// tensor it ever holds).
    pub bytes_pooled: u64,
}

impl PlanStats {
    /// Fraction of buffer bytes eliminated by pooling.
    pub fn bytes_saved_frac(&self) -> f64 {
        if self.bytes_one_per_node == 0 {
            return 0.0;
        }
        1.0 - self.bytes_pooled as f64 / self.bytes_one_per_node as f64
    }
}

/// A buffer-slot assignment for one graph under one execution order.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// node id -> slot index (None for sources and values that never
    /// materialize).
    pub slot_of: Vec<Option<usize>>,
    /// Number of slots in the pool.
    pub num_slots: usize,
    /// expire[p] = planned values that die right after executing position
    /// `p` (their slot may be reused from position `p+1` on). Graph
    /// outputs never appear here.
    pub expire: Vec<Vec<NodeId>>,
    /// Per-slot capacity in f32 elements (the largest value the slot ever
    /// holds) — what sizes the steady-state [`Workspace`] arena.
    pub slot_elems: Vec<usize>,
    pub stats: PlanStats,
}

impl MemoryPlan {
    /// Plan buffers for executing `g`'s compute nodes in `order`; only
    /// nodes with `materialize[id]` are given slots (every element of
    /// `order` must be a compute node id; sources are read from their own
    /// storage and never planned).
    pub fn new(g: &Graph, order: &[NodeId], materialize: &[bool]) -> MemoryPlan {
        let nn = g.nodes.len();
        let mut pos = vec![usize::MAX; nn];
        for (p, &id) in order.iter().enumerate() {
            debug_assert!(!g.node(id).op.is_source(), "sources are not planned");
            pos[id] = p;
        }
        // Last-use position per ordered node; usize::MAX = live forever.
        let mut last = vec![0usize; nn];
        for &id in order {
            last[id] = pos[id];
        }
        for &id in order {
            for &i in &g.node(id).inputs {
                if pos[i] != usize::MAX && last[i] != usize::MAX {
                    last[i] = last[i].max(pos[id]);
                }
            }
        }
        for &o in &g.outputs {
            if pos[o] != usize::MAX {
                last[o] = usize::MAX;
            }
        }

        let mut slot_of: Vec<Option<usize>> = vec![None; nn];
        let mut slot_bytes: Vec<u64> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        let mut expire: Vec<Vec<NodeId>> = vec![Vec::new(); order.len()];
        let mut live = 0usize;
        let mut peak = 0usize;
        let mut planned_values = 0usize;
        let mut bytes_one = 0u64;
        let mut slot_elems: Vec<usize> = Vec::new();
        for (p, &id) in order.iter().enumerate() {
            if materialize[id] {
                let bytes = g.node(id).out_elems() * 4;
                bytes_one += bytes;
                planned_values += 1;
                let s = match free.pop() {
                    Some(s) => s,
                    None => {
                        slot_bytes.push(0);
                        slot_elems.push(0);
                        slot_bytes.len() - 1
                    }
                };
                slot_of[id] = Some(s);
                slot_bytes[s] = slot_bytes[s].max(bytes);
                slot_elems[s] = slot_elems[s].max(g.node(id).out_elems() as usize);
                live += 1;
                peak = peak.max(live);
            }
            // Release every distinct planned value whose last use is this
            // position.
            let ins = &g.node(id).inputs;
            for (ii, &i) in ins.iter().enumerate() {
                if ins[..ii].contains(&i) {
                    continue;
                }
                if pos[i] != usize::MAX && last[i] == p {
                    if let Some(si) = slot_of[i] {
                        free.push(si);
                        expire[p].push(i);
                        live -= 1;
                    }
                }
            }
            // A planned value nobody consumes (and that is not an output)
            // dies at its own definition.
            if last[id] == p {
                if let Some(s) = slot_of[id] {
                    free.push(s);
                    expire[p].push(id);
                    live -= 1;
                }
            }
        }

        let stats = PlanStats {
            planned_values,
            slots: slot_bytes.len(),
            peak_live: peak,
            bytes_one_per_node: bytes_one,
            bytes_pooled: slot_bytes.iter().sum(),
        };
        MemoryPlan { slot_of, num_slots: slot_bytes.len(), expire, slot_elems, stats }
    }

    /// Plan for the straight-line (node-id) execution order, where every
    /// compute value materializes.
    pub fn straight_line(g: &Graph) -> MemoryPlan {
        let order: Vec<NodeId> = g.compute_nodes();
        let materialize = vec![true; g.nodes.len()];
        MemoryPlan::new(g, &order, &materialize)
    }
}

/// Compile-time sizing of every scratch buffer the steady-state engine
/// needs — the liveness pass extended from "how many value slots" to "how
/// big is the whole per-model arena": im2col patch matrices, GEMM staging
/// and A-pack scratch, scatter staging, and the intra-group running
/// buffers. Computed once per `Compiler::compile`; [`Workspace::new`]
/// turns it into real buffers that `infer()` borrows mutably on every
/// call, so steady state allocates nothing.
///
/// The transformer kernel set (batched MatMul, Transpose, Embedding,
/// Slice, Pad) needs **no dedicated scratch**: every operand is read
/// straight from a slot/group buffer and every result is written straight
/// into one, so the attention path (QK^T → scale → softmax → AV) is
/// covered by `slot_elems`/`group_elems` alone — the per-op `out_elems`
/// maxima this pass already takes over all non-source nodes. (MatMul's
/// GEMM still packs panels *inside* `gemm`, so batched matmul is outside
/// the zero-allocation guarantee — see ROADMAP.)
#[derive(Debug, Clone, Default)]
pub struct WorkspaceSpec {
    /// Per-slot f32 capacity (from [`MemoryPlan::slot_elems`]).
    pub slot_elems: Vec<usize>,
    /// Capacity of each of the two ping-pong buffers holding
    /// intra-group intermediates that never materialize into a slot.
    pub group_elems: usize,
    /// Largest im2col patch matrix (`n*oh*ow × i*kh*kw`) of any
    /// groups=1 conv.
    pub patches_elems: usize,
    /// Largest GEMM conv staging buffer (`n*oh*ow × o`) before the NCHW
    /// scatter.
    pub gemm_out_elems: usize,
    /// Largest transposed conv weight matrix (`i*kh*kw × o`) — used only
    /// when pre-packing is off and the transpose happens per call.
    pub wt_elems: usize,
    /// Per-*causal*-attention K/V cache row widths (`batch·heads × d_head`
    /// elements per cached position, per tensor), discovered by
    /// [`crate::exec::decode::attention_specs`]. A
    /// [`DecodeSession`](crate::exec::decode::DecodeSession) holds
    /// `2 × row × max_seq` elements per entry — over a whole decoder this
    /// is the classic `layers × 2 × heads × max_seq × d_head` cache-slot
    /// budget; see [`WorkspaceSpec::kv_cache_elems`]. The caches are
    /// per-session state (not part of the shared arena), so they are
    /// excluded from [`WorkspaceSpec::bytes`].
    pub kv_rows: Vec<usize>,
}

impl WorkspaceSpec {
    /// Size the arena for executing `g` under `plan` (`materialize` as in
    /// [`MemoryPlan::new`]). Conv buffers are sized over every groups=1
    /// conv so the spec stays valid whether a layer later runs FKW,
    /// deep-reuse or plain GEMM.
    pub fn for_graph(g: &Graph, plan: &MemoryPlan, materialize: &[bool]) -> WorkspaceSpec {
        let mut spec = WorkspaceSpec { slot_elems: plan.slot_elems.clone(), ..Default::default() };
        spec.kv_rows = crate::exec::decode::attention_specs(g)
            .iter()
            .filter(|a| a.causal)
            .map(|a| a.row_elems())
            .collect();
        for n in &g.nodes {
            if n.op.is_source() {
                continue;
            }
            if !materialize[n.id] {
                spec.group_elems = spec.group_elems.max(n.out_elems() as usize);
            }
            if let OpKind::Conv2d { groups: 1, .. } = n.op {
                let Some(wid) = n
                    .inputs
                    .iter()
                    .copied()
                    .find(|&i| matches!(g.node(i).op, OpKind::Weight))
                else {
                    continue;
                };
                let ws = &g.node(wid).shape;
                let (o, i, kh, kw) = (ws[0], ws[1], ws[2], ws[3]);
                let (nb, oh, ow) = (n.shape[0], n.shape[2], n.shape[3]);
                let rows = nb * oh * ow;
                let cols = i * kh * kw;
                spec.patches_elems = spec.patches_elems.max(rows * cols);
                spec.gemm_out_elems = spec.gemm_out_elems.max(rows * o);
                spec.wt_elems = spec.wt_elems.max(cols * o);
            }
        }
        spec
    }

    /// Total f32 elements a decode session's K/V caches occupy at
    /// `max_seq` positions (`Σ causal attentions 2 × bh·d_head × max_seq`).
    pub fn kv_cache_elems(&self, max_seq: usize) -> usize {
        self.kv_rows.iter().map(|&r| 2 * r * max_seq).sum()
    }

    /// Total arena footprint in bytes under `cfg` (reported by
    /// `CompiledModel::report`). Includes the int8 A-pack scratch — one
    /// 4-byte-aligned i8 band per pool thread — whether or not the plan
    /// quantizes anything: the arena is sized at compile time and the
    /// int8 bands cost 1/4 of the f32 bands they sit beside.
    pub fn bytes(&self, cfg: &GemmConfig) -> u64 {
        let slots: usize = self.slot_elems.iter().sum();
        let scratch = prepacked_scratch_elems(cfg) * cfg.resolved_threads();
        let qscratch_bytes = qgemm_scratch_band_bytes(cfg) * cfg.resolved_threads();
        (slots
            + 2 * self.group_elems
            + self.patches_elems
            + self.gemm_out_elems
            + self.wt_elems
            + scratch) as u64
            * 4
            + qscratch_bytes as u64
    }
}

/// The per-model scratch arena of the steady-state engine: every buffer
/// `infer()` needs, allocated **once** from a [`WorkspaceSpec`] and reused
/// across calls. `CompiledModel` keeps one behind a mutex and lends it to
/// each inference; after warm-up the hot loop touches only this memory.
#[derive(Debug)]
pub struct Workspace {
    /// Planned value slots (capacity from the liveness pass).
    pub slots: Vec<Vec<f32>>,
    /// Ping-pong buffers for intra-group intermediates.
    pub group: [Vec<f32>; 2],
    /// im2col patch matrix staging.
    pub patches: Vec<f32>,
    /// GEMM conv output staging (pre-scatter).
    pub gemm_out: Vec<f32>,
    /// Per-call transposed conv weight (pre-packing off only).
    pub wt: Vec<f32>,
    /// A-panel pack scratch for `gemm_prepacked`, one band per pool
    /// thread.
    pub gemm_scratch: Vec<f32>,
    /// Quantized A-panel pack scratch for the int8 kernel
    /// (`qgemm_prepacked`), one 4-byte-aligned i8 band per pool thread —
    /// the int8 steady path quantizes activations into this arena region
    /// instead of allocating.
    pub qgemm_scratch: Vec<i8>,
}

impl Workspace {
    pub fn new(spec: &WorkspaceSpec, cfg: &GemmConfig) -> Workspace {
        Workspace {
            slots: spec.slot_elems.iter().map(|&e| vec![0.0; e]).collect(),
            group: [vec![0.0; spec.group_elems], vec![0.0; spec.group_elems]],
            patches: vec![0.0; spec.patches_elems],
            gemm_out: vec![0.0; spec.gemm_out_elems],
            wt: vec![0.0; spec.wt_elems],
            gemm_scratch: vec![
                0.0;
                prepacked_scratch_elems(cfg) * cfg.resolved_threads()
            ],
            qgemm_scratch: vec![
                0i8;
                qgemm_scratch_band_bytes(cfg) * cfg.resolved_threads()
            ],
        }
    }

    /// Resident bytes of the arena.
    pub fn bytes(&self) -> u64 {
        let slots: usize = self.slots.iter().map(|s| s.len()).sum();
        (slots
            + self.group[0].len()
            + self.group[1].len()
            + self.patches.len()
            + self.gemm_out.len()
            + self.wt.len()
            + self.gemm_scratch.len()) as u64
            * 4
            + self.qgemm_scratch.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo::NetBuilder;
    use crate::graph::Act;

    fn chain_cnn() -> Graph {
        let mut b = NetBuilder::new("chain", &[1, 3, 16, 16]);
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        let skip = b.cur();
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        let t = b.cur();
        b.add_residual(skip, t);
        b.gap();
        b.dense(10);
        b.finish()
    }

    #[test]
    fn pool_is_much_smaller_than_one_per_node() {
        let g = chain_cnn();
        let plan = MemoryPlan::straight_line(&g);
        assert_eq!(plan.stats.planned_values, g.compute_nodes().len());
        assert!(
            plan.stats.slots * 2 < plan.stats.planned_values,
            "slots {} vs values {}",
            plan.stats.slots,
            plan.stats.planned_values
        );
        assert!(plan.stats.peak_live <= plan.stats.slots);
        assert!(plan.stats.bytes_pooled < plan.stats.bytes_one_per_node);
        assert!(plan.stats.bytes_saved_frac() > 0.5);
    }

    #[test]
    fn shared_slots_have_disjoint_live_ranges() {
        let g = chain_cnn();
        let order = g.compute_nodes();
        let materialize = vec![true; g.nodes.len()];
        let plan = MemoryPlan::new(&g, &order, &materialize);
        // Replay: walk the order; a slot must never be written while the
        // previous occupant is still live.
        let mut occupant: Vec<Option<NodeId>> = vec![None; plan.num_slots];
        let mut dead = vec![false; g.nodes.len()];
        for (p, &id) in order.iter().enumerate() {
            let s = plan.slot_of[id].unwrap();
            if let Some(prev) = occupant[s] {
                assert!(dead[prev], "slot {s} reused while node {prev} lives");
            }
            occupant[s] = Some(id);
            for &d in &plan.expire[p] {
                dead[d] = true;
            }
        }
        // Outputs never expire.
        for &o in &g.outputs {
            assert!(!dead[o], "output {o} was expired");
        }
    }

    #[test]
    fn unmaterialized_values_get_no_slot() {
        let g = chain_cnn();
        let order = g.compute_nodes();
        // Materialize only every third value (plus the output).
        let mut materialize = vec![false; g.nodes.len()];
        for (i, &id) in order.iter().enumerate() {
            if i % 3 == 0 {
                materialize[id] = true;
            }
        }
        for &o in &g.outputs {
            materialize[o] = true;
        }
        let plan = MemoryPlan::new(&g, &order, &materialize);
        for &id in &order {
            assert_eq!(plan.slot_of[id].is_some(), materialize[id], "node {id}");
        }
        let planned = order.iter().filter(|&&id| materialize[id]).count();
        assert_eq!(plan.stats.planned_values, planned);
        assert!(plan.stats.slots <= planned);
        // Expire lists contain only planned values.
        for evs in &plan.expire {
            for &d in evs {
                assert!(materialize[d]);
            }
        }
    }

    /// The transformer zoo goes through the same liveness pass: every
    /// planned attention intermediate (rank-3 scores, probs, context)
    /// fits its slot, and the attention path needs no conv scratch — the
    /// arena is slots + group buffers only.
    #[test]
    fn workspace_sizes_cover_the_attention_path() {
        let g = crate::graph::zoo::by_name("demo-transformer", 1);
        let plan = MemoryPlan::straight_line(&g);
        for id in g.compute_nodes() {
            let s = plan.slot_of[id].expect("straight line plans every value");
            assert!(
                plan.slot_elems[s] >= g.node(id).out_elems() as usize,
                "slot {s} too small for node {id}"
            );
        }
        let materialize = vec![true; g.nodes.len()];
        let spec = WorkspaceSpec::for_graph(&g, &plan, &materialize);
        assert_eq!(spec.patches_elems, 0, "attention path must need no im2col scratch");
        assert_eq!(spec.gemm_out_elems, 0);
        // Scores/probs ([1, 32, 32]) are among the planned values.
        let scores = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, OpKind::Softmax))
            .expect("transformer has a softmax");
        assert!(spec.slot_elems[plan.slot_of[scores.id].unwrap()] >= 32 * 32);
    }

    /// The extended liveness pass sizes decode K/V cache slots: one
    /// `batch·heads × d_head` row pair per causal attention, i.e. the
    /// classic `layers × 2 × heads × max_seq × d_head` budget.
    #[test]
    fn workspace_sizes_kv_cache_slots_for_causal_decoders() {
        // demo-transformer-causal: 2 layers, folded heads (bh=1, dh=64).
        let g = crate::graph::zoo::by_name("demo-transformer-causal", 1);
        let plan = MemoryPlan::straight_line(&g);
        let materialize = vec![true; g.nodes.len()];
        let spec = WorkspaceSpec::for_graph(&g, &plan, &materialize);
        assert_eq!(spec.kv_rows, vec![64, 64]);
        assert_eq!(spec.kv_cache_elems(32), 2 * 2 * 64 * 32);
        // gpt2 frontend (2 layers, 12 heads, d_head 64): per-head rows.
        let g = crate::graph::zoo::nlp::gpt2_frontend_layers(1, 2);
        let plan = MemoryPlan::straight_line(&g);
        let materialize = vec![true; g.nodes.len()];
        let spec = WorkspaceSpec::for_graph(&g, &plan, &materialize);
        assert_eq!(spec.kv_rows, vec![12 * 64, 12 * 64]);
        assert_eq!(spec.kv_cache_elems(16), 2 * 2 * 12 * 16 * 64);
        // Encoders carry no decode cache slots.
        let g = crate::graph::zoo::by_name("demo-transformer", 1);
        let plan = MemoryPlan::straight_line(&g);
        let materialize = vec![true; g.nodes.len()];
        let spec = WorkspaceSpec::for_graph(&g, &plan, &materialize);
        assert!(spec.kv_rows.is_empty());
    }

    #[test]
    fn outputs_keep_their_slot_forever() {
        let g = chain_cnn();
        let plan = MemoryPlan::straight_line(&g);
        let out = g.outputs[0];
        for evs in &plan.expire {
            assert!(!evs.contains(&out));
        }
    }
}
