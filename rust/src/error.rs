//! Typed errors of the serving runtime (ISSUE-6 tentpole).
//!
//! The ROADMAP's north star is serving heavy traffic, and a serving loop
//! cannot tell its callers "something panicked somewhere" — admission
//! control, deadline handling and client retry policy all hinge on *which*
//! failure happened. [`XgenError`] is that taxonomy: the recoverable
//! subset of what used to be panics/unwraps/anyhow strings, as a typed,
//! cloneable value that crosses the coordinator's reply channels intact.
//!
//! Layering rules:
//!
//! * Functions keep returning `anyhow::Result` (the crate-wide idiom); a
//!   typed failure is an `XgenError` *inside* the `anyhow::Error`
//!   (`XgenError: std::error::Error`, so `?` and `.into()` just work).
//! * [`XgenError::of`] recovers the typed value from any `anyhow::Error`
//!   (the CLI prints `error[Code]: …` and exits nonzero; tests match on
//!   variants instead of message substrings).
//! * [`XgenError::classify`] is the serving boundary: whatever error a
//!   request produced becomes a typed value on the wire — already-typed
//!   errors pass through, anything else becomes [`XgenError::Internal`].
//! * Panics stay panics for true internal invariants; the serving layer
//!   catches them at isolation points and reports
//!   [`XgenError::WorkerPanic`].

use std::fmt;

/// One typed failure of compilation, inference, decoding or serving.
///
/// `PartialEq` compares variants *and* payloads; use
/// [`XgenError::code`] when only the category matters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum XgenError {
    /// Input tensor count / shape / length does not match the compiled
    /// graph. Returned before any execution starts.
    ShapeMismatch { expected: String, got: String },
    /// A token id is outside the decoder's vocabulary.
    VocabOutOfRange { token: u32, vocab: usize },
    /// A prompt or step would exceed the session's positional capacity.
    /// `at` is the current length, `want` the tokens being added.
    SeqOverflow { at: usize, want: usize, max_seq: usize },
    /// The bounded submission queue is full — the request was shed
    /// immediately, nothing was enqueued. `retry_after_ms` is the
    /// server's estimate of when capacity frees up (observed queue depth
    /// × recent mean service time; at least 1 ms) — the backoff seed the
    /// `submit_with_retry` helpers start from.
    Overloaded { depth: usize, capacity: usize, retry_after_ms: u64 },
    /// The per-request deadline expired. For streaming generation the
    /// tokens decoded before the deadline were already delivered — the
    /// partial generation stands.
    DeadlineExceeded { elapsed_ms: u64 },
    /// The client dropped its receiver; the remaining work was abandoned.
    Cancelled,
    /// A worker job panicked. The pool and the per-model workspace
    /// self-heal; only this request fails.
    WorkerPanic { detail: String },
    /// The steady engine failed at serve time and the fallback reference
    /// path failed too (a successful fallback is invisible to the caller
    /// and only counted in stats).
    EngineFallback { detail: String },
    /// Non-finite values surfaced at a guarded point (e.g. serving-time
    /// logits).
    NonFinite { at: String },
    /// A `submit_with_retry` helper exhausted its attempt budget — every
    /// attempt was shed with [`XgenError::Overloaded`]. `last_depth` is
    /// the queue depth observed on the final attempt.
    RetryExhausted { attempts: usize, last_depth: usize },
    /// The server thread is gone (shut down or crashed at startup).
    ServerGone,
    /// A structural graph invariant failed — topological order, payload
    /// consistency, const-store sync or the fusion materialization
    /// invariant. `pass` names the pipeline stage that produced the
    /// offending graph ("builder" when it never entered the pipeline).
    InvalidGraph { pass: String, detail: String },
    /// A memory-plan invariant failed — two simultaneously-live values
    /// share a slot, a slot is under-sized for one of its users, or an
    /// arena region overlaps/overflows. `pass` names the checker stage.
    InvalidPlan { pass: String, detail: String },
    /// A semantic dataflow analysis (`xgen::analyze`) proved a property
    /// violation at compile time. `code` is the analysis-level reason
    /// ("guaranteed-nan", "guaranteed-inf", "trace-unsafe"), `node`/`name`
    /// identify the blamed IR node — the *origin* of the problem, not a
    /// downstream victim it propagated to.
    AnalysisDiagnostic { code: String, node: usize, name: String, detail: String },
    /// Anything else: an internal invariant or a wrapped lower-level
    /// error that has no dedicated variant.
    Internal { detail: String },
}

impl XgenError {
    /// Stable short code naming the variant — what the CLI prints inside
    /// `error[...]` and what dashboards should key on.
    pub fn code(&self) -> &'static str {
        match self {
            XgenError::ShapeMismatch { .. } => "ShapeMismatch",
            XgenError::VocabOutOfRange { .. } => "VocabOutOfRange",
            XgenError::SeqOverflow { .. } => "SeqOverflow",
            XgenError::Overloaded { .. } => "Overloaded",
            XgenError::DeadlineExceeded { .. } => "DeadlineExceeded",
            XgenError::Cancelled => "Cancelled",
            XgenError::WorkerPanic { .. } => "WorkerPanic",
            XgenError::EngineFallback { .. } => "EngineFallback",
            XgenError::NonFinite { .. } => "NonFinite",
            XgenError::RetryExhausted { .. } => "RetryExhausted",
            XgenError::ServerGone => "ServerGone",
            XgenError::InvalidGraph { .. } => "InvalidGraph",
            XgenError::InvalidPlan { .. } => "InvalidPlan",
            XgenError::AnalysisDiagnostic { .. } => "AnalysisDiagnostic",
            XgenError::Internal { .. } => "Internal",
        }
    }

    /// Re-label a verifier error with the pipeline stage it fired in.
    /// `Graph::validate` reports against a generic "graph" pass because it
    /// cannot know who mutated the graph; the pipeline verifier calls this
    /// so a failure reads `invalid graph after pass 'fuse': …`. Non-verifier
    /// errors pass through unchanged.
    pub fn with_pass(self, pass: &str) -> XgenError {
        match self {
            XgenError::InvalidGraph { detail, .. } => {
                XgenError::InvalidGraph { pass: pass.to_string(), detail }
            }
            XgenError::InvalidPlan { detail, .. } => {
                XgenError::InvalidPlan { pass: pass.to_string(), detail }
            }
            other => other,
        }
    }

    /// The typed error inside an `anyhow::Error`, if there is one.
    pub fn of(err: &anyhow::Error) -> Option<&XgenError> {
        err.downcast_ref::<XgenError>()
    }

    /// Serving-boundary conversion: pass a typed error through, wrap
    /// anything else as [`XgenError::Internal`] (with the full anyhow
    /// context chain in the detail).
    pub fn classify(err: &anyhow::Error) -> XgenError {
        match XgenError::of(err) {
            Some(e) => e.clone(),
            None => XgenError::Internal { detail: format!("{err:#}") },
        }
    }
}

impl fmt::Display for XgenError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XgenError::ShapeMismatch { expected, got } => {
                write!(f, "shape mismatch: expected {expected}, got {got}")
            }
            XgenError::VocabOutOfRange { token, vocab } => {
                write!(f, "token id {token} out of range for vocab {vocab}")
            }
            // Two spellings, one variant: a full sequence (nothing can be
            // added) vs. a prompt that does not fit from the current
            // position. Tests and callers match on these phrases.
            XgenError::SeqOverflow { at, want, max_seq } => {
                if at >= max_seq {
                    write!(
                        f,
                        "sequence is full ({max_seq} positions) — call reset() or raise max_seq"
                    )
                } else {
                    write!(
                        f,
                        "prompt of {want} tokens exceeds max_seq {max_seq} (at position {at})"
                    )
                }
            }
            XgenError::Overloaded { depth, capacity, retry_after_ms } => {
                write!(
                    f,
                    "server overloaded: {depth} requests queued (capacity {capacity}) — \
                     retry in ~{retry_after_ms} ms"
                )
            }
            XgenError::DeadlineExceeded { elapsed_ms } => {
                write!(f, "deadline exceeded after {elapsed_ms} ms")
            }
            XgenError::Cancelled => write!(f, "request cancelled (receiver dropped)"),
            XgenError::WorkerPanic { detail } => {
                write!(f, "a worker panicked while serving this request: {detail}")
            }
            XgenError::EngineFallback { detail } => {
                write!(f, "steady engine failed and the reference fallback failed too: {detail}")
            }
            XgenError::NonFinite { at } => {
                write!(f, "non-finite values detected at {at}")
            }
            XgenError::RetryExhausted { attempts, last_depth } => {
                write!(
                    f,
                    "gave up after {attempts} overloaded attempts (last observed depth \
                     {last_depth})"
                )
            }
            XgenError::ServerGone => write!(f, "server shut down"),
            XgenError::InvalidGraph { pass, detail } => {
                write!(f, "invalid graph after pass '{pass}': {detail}")
            }
            XgenError::InvalidPlan { pass, detail } => {
                write!(f, "invalid memory plan after pass '{pass}': {detail}")
            }
            XgenError::AnalysisDiagnostic { code, node, name, detail } => {
                write!(f, "analysis[{code}] at node {node} ('{name}'): {detail}")
            }
            XgenError::Internal { detail } => write!(f, "{detail}"),
        }
    }
}

impl std::error::Error for XgenError {}

/// Best-effort human-readable message from a caught panic payload (the
/// `Box<dyn Any>` that `catch_unwind` returns).
pub fn panic_detail(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_display_is_matchable() {
        let e = XgenError::VocabOutOfRange { token: 300, vocab: 256 };
        assert_eq!(e.code(), "VocabOutOfRange");
        assert!(e.to_string().contains("out of range"));
        let full = XgenError::SeqOverflow { at: 4, want: 1, max_seq: 4 };
        assert!(full.to_string().contains("full"));
        let long = XgenError::SeqOverflow { at: 0, want: 9, max_seq: 4 };
        assert!(long.to_string().contains("exceeds max_seq"));
        let shed = XgenError::Overloaded { depth: 8, capacity: 8, retry_after_ms: 12 };
        assert_eq!(shed.code(), "Overloaded");
        assert!(shed.to_string().contains("retry in ~12 ms"));
        let gave_up = XgenError::RetryExhausted { attempts: 5, last_depth: 8 };
        assert_eq!(gave_up.code(), "RetryExhausted");
        assert!(gave_up.to_string().contains("gave up after 5"));
    }

    #[test]
    fn verifier_errors_carry_the_pass() {
        let e = XgenError::InvalidGraph { pass: "graph".into(), detail: "cycle".into() };
        assert_eq!(e.code(), "InvalidGraph");
        let e = e.with_pass("fuse");
        assert!(e.to_string().contains("after pass 'fuse'"));
        let p = XgenError::InvalidPlan { pass: "plan".into(), detail: "alias".into() };
        assert_eq!(p.code(), "InvalidPlan");
        assert!(p.to_string().contains("invalid memory plan"));
        // Non-verifier variants are untouched by with_pass.
        assert_eq!(XgenError::Cancelled.with_pass("fuse"), XgenError::Cancelled);
    }

    #[test]
    fn analysis_diagnostics_name_the_blamed_node() {
        let d = XgenError::AnalysisDiagnostic {
            code: "guaranteed-nan".into(),
            node: 7,
            name: "sqrt_bad".into(),
            detail: "sqrt of a strictly-negative range".into(),
        };
        assert_eq!(d.code(), "AnalysisDiagnostic");
        assert!(d.to_string().contains("analysis[guaranteed-nan]"));
        assert!(d.to_string().contains("node 7"));
        assert!(d.to_string().contains("sqrt_bad"));
        // Analysis diagnostics already carry their origin; with_pass is
        // a verifier re-label and must leave them untouched.
        assert_eq!(d.clone().with_pass("fuse"), d);
    }

    #[test]
    fn round_trips_through_anyhow() {
        let e: anyhow::Error = XgenError::Cancelled.into();
        assert_eq!(XgenError::of(&e), Some(&XgenError::Cancelled));
        assert_eq!(XgenError::classify(&e), XgenError::Cancelled);
        let plain = anyhow::anyhow!("just a string");
        assert!(XgenError::of(&plain).is_none());
        assert_eq!(XgenError::classify(&plain).code(), "Internal");
    }

    #[test]
    fn panic_detail_extracts_strings() {
        let p: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_detail(p.as_ref()), "boom");
        let p: Box<dyn std::any::Any + Send> = Box::new(String::from("owned"));
        assert_eq!(panic_detail(p.as_ref()), "owned");
        let p: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert!(panic_detail(p.as_ref()).contains("non-string"));
    }
}
