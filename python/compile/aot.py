"""AOT lowering: JAX/Pallas models → HLO **text** artifacts for the Rust
PJRT runtime.

HLO text (not `HloModuleProto.serialize()`) is the interchange format: jax
≥0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Weights are closed over (baked as HLO constants), so each artifact is a
self-contained `f(x) -> logits/image` the Rust side feeds raw input
tensors. `artifacts/meta.json` records input/output shapes per artifact.

Runs once under `make artifacts`; never on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T


def to_hlo_text(fn, *example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def load_or_train(out_dir, steps):
    wpath = os.path.join(out_dir, "cnn_weights.npz")
    ppath = os.path.join(out_dir, "cnn_pattern_weights.npz")
    if not (os.path.exists(wpath) and os.path.exists(ppath)):
        T.main(out_dir=out_dir, steps=steps)
    dense = {k: jnp.asarray(v) for k, v in np.load(wpath).items()}
    praw = np.load(ppath)
    pparams = {k: jnp.asarray(v) for k, v in praw.items() if not k.startswith("mask_")}
    pmasks = {k[5:]: jnp.asarray(v) for k, v in praw.items() if k.startswith("mask_")}
    return dense, pparams, pmasks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--train-steps", type=int, default=300)
    args = ap.parse_args()
    out_dir = args.out if os.path.isdir(os.path.dirname(args.out) or ".") else "../artifacts"
    os.makedirs(out_dir, exist_ok=True)

    dense, pparams, pmasks = load_or_train(out_dir, args.train_steps)
    meta = {}

    def emit(name, fn, in_shape):
        x = jax.ShapeDtypeStruct(in_shape, jnp.float32)
        text = to_hlo_text(fn, x)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta[name] = {"input": list(in_shape), "chars": len(text)}
        print(f"wrote {name}: {len(text)} chars")

    for batch in (1, 4):
        emit(
            f"cnn_dense_b{batch}",
            lambda x: (M.cnn_forward(dense, x, variant="dense"),),
            (batch, *M.CNN_IN),
        )
        # Pattern variant: pruned weights through the Pallas kernel path.
        emit(
            f"cnn_pattern_b{batch}",
            lambda x: (M.cnn_forward(pparams, x, variant="pattern", masks=pmasks),),
            (batch, *M.CNN_IN),
        )

    wdsr = M.init_wdsr(1)
    emit("wdsr_b1", lambda x: (M.wdsr_forward(wdsr, x),), (1, *M.WDSR_IN))
    wmasks = M.elite8_masks(wdsr, ["r1b", "r2b"])
    wpruned = {k: (v * wmasks[k] if k in wmasks else v) for k, v in wdsr.items()}
    emit(
        "wdsr_pattern_b1",
        lambda x: (M.wdsr_forward(wpruned, x, variant="pattern", masks=wmasks),),
        (1, *M.WDSR_IN),
    )

    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    print(f"meta.json: {len(meta)} artifacts")


if __name__ == "__main__":
    main()
