"""Build-time training: the *measured* accuracy leg of the reproduction.

Trains `SmallCNN` on a deterministic synthetic 8-class shape corpus, then
prunes it with each scheme (pattern / block / magnitude / structured),
fine-tunes, and writes the accuracy table to `artifacts/accuracy.json` —
the measured counterpart of the paper's "same accuracy" claims and the
Fig 6 accuracy ordering (non-structured ≥ pattern ≥ block ≥ structured).

Runs once under `make artifacts`; never on the request path.
"""

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import ref

CLASSES = M.CNN_CLASSES


def make_dataset(n, seed=0):
    """8 distinguishable procedural classes on 3x24x24 images."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 3, 24, 24), np.float32)
    ys = rng.integers(0, CLASSES, size=n)
    yy, xx = np.mgrid[0:24, 0:24].astype(np.float32)
    for i in range(n):
        c = ys[i]
        phase = rng.uniform(0, 2 * np.pi)
        freq = 0.25 + 0.045 * c
        if c % 4 == 0:
            base = np.sin(freq * xx + phase)
        elif c % 4 == 1:
            base = np.sin(freq * yy + phase)
        elif c % 4 == 2:
            base = np.sin(freq * (xx + yy) + phase)
        else:
            r2 = (xx - 12) ** 2 + (yy - 12) ** 2
            base = np.sin(freq * np.sqrt(r2) + phase)
        for ch in range(3):
            gain = 1.0 if (c < 4) == (ch % 2 == 0) else 0.75
            xs[i, ch] = gain * base + rng.normal(0, 1.1, (24, 24))
    return jnp.asarray(xs), jnp.asarray(ys)


def loss_fn(params, x, y, variant="dense", masks=None):
    logits = M.cnn_forward(params, x, variant=variant, masks=masks)
    onehot = jax.nn.one_hot(y, CLASSES)
    return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))


def accuracy(params, x, y, variant="dense", masks=None):
    logits = M.cnn_forward(params, x, variant=variant, masks=masks)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


def sgd_train(params, xs, ys, steps, lr=0.05, bs=64, masks=None, mask_weights=False, seed=0):
    """Plain-momentum SGD; if mask_weights, conv weights are re-masked after
    every step (straight-through pruned fine-tuning)."""
    grad = jax.jit(jax.grad(lambda p, x, y: loss_fn(p, x, y)))
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    n = xs.shape[0]
    for _ in range(steps):
        idx = rng.integers(0, n, bs)
        g = grad(params, xs[idx], ys[idx])
        vel = jax.tree_util.tree_map(lambda v, gg: 0.9 * v - lr * gg, vel, g)
        params = jax.tree_util.tree_map(lambda p, v: p + v, params, vel)
        if mask_weights and masks is not None:
            params = dict(params)
            for name, m in masks.items():
                if name in params:
                    params[name] = params[name] * m
    return params


def structured_masks(params, conv_names, keep=4.0 / 9.0):
    """Filter-pruning masks: keep the strongest `keep` fraction of filters
    entirely (whole-matrix granularity)."""
    masks = {}
    for name in conv_names:
        w = params[name]
        energy = jnp.sum(w * w, axis=(1, 2, 3))
        kth = jnp.quantile(energy, 1.0 - keep)
        m = (energy >= kth).astype(jnp.float32)
        masks[name] = jnp.broadcast_to(m[:, None, None, None], w.shape)
    return masks


def magnitude_masks(params, conv_names, keep=4.0 / 9.0):
    masks = {}
    for name in conv_names:
        w = params[name]
        kth = jnp.quantile(jnp.abs(w).reshape(-1), 1.0 - keep)
        masks[name] = (jnp.abs(w) >= kth).astype(jnp.float32)
    return masks


def main(out_dir="../artifacts", steps=300, finetune=120):
    os.makedirs(out_dir, exist_ok=True)
    xs, ys = make_dataset(2048, seed=0)
    xt, yt = make_dataset(512, seed=1)
    conv_names = ["c1", "c2", "c3"]

    params = M.init_cnn(0)
    params = sgd_train(params, xs, ys, steps)
    acc = {"dense": accuracy(params, xt, yt)}

    # Pattern pruning (4-of-9 = 44% density) + fine-tune.
    pmasks = M.elite8_masks(params, conv_names)
    pparams = {k: (v * pmasks[k] if k in pmasks else v) for k, v in params.items()}
    pparams = sgd_train(pparams, xs, ys, finetune, masks=pmasks, mask_weights=True)
    acc["pattern"] = accuracy(pparams, xt, yt, variant="dense")

    # Magnitude (non-structured) at the same density.
    mmasks = magnitude_masks(params, conv_names)
    mparams = {k: (v * mmasks[k] if k in mmasks else v) for k, v in params.items()}
    mparams = sgd_train(mparams, xs, ys, finetune, masks=mmasks, mask_weights=True)
    acc["non_structured"] = accuracy(mparams, xt, yt)

    # Structured (filter) pruning at the same density.
    smasks = structured_masks(params, conv_names)
    sparams = {k: (v * smasks[k] if k in smasks else v) for k, v in params.items()}
    sparams = sgd_train(sparams, xs, ys, finetune, masks=smasks, mask_weights=True)
    acc["structured"] = accuracy(sparams, xt, yt)

    with open(os.path.join(out_dir, "accuracy.json"), "w") as f:
        json.dump(acc, f, indent=1, sort_keys=True)

    # Save dense + pattern weights (and masks) for aot.py.
    np.savez(
        os.path.join(out_dir, "cnn_weights.npz"),
        **{k: np.asarray(v) for k, v in params.items()},
    )
    np.savez(
        os.path.join(out_dir, "cnn_pattern_weights.npz"),
        **{k: np.asarray(v) for k, v in pparams.items()},
        **{"mask_" + k: np.asarray(v) for k, v in pmasks.items()},
    )
    print("accuracy:", json.dumps(acc, sort_keys=True))
    return acc


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 300
    main(steps=steps)
