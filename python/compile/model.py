"""L2: the demonstration models, written in JAX and calling the L1 Pallas
kernels, AOT-lowered by `aot.py` into the artifacts the Rust runtime
serves.

* `SmallCNN` — an 8-class image classifier (the car-classification /
  quickstart workload) with three execution variants: `dense` (lax.conv),
  `pattern` (4-entry pattern-pruned convs through the Pallas pattern-GEMM
  kernel) and `block` (block-pruned dense head through the Pallas
  block-sparse kernel).
* `wdsr_tiny` — a WDSR-style ×2 super-resolution body (use case III).

Parameters are plain pytrees (dicts); `init_*` builds them deterministically
from a seed so Python and Rust agree on shapes.
"""

import jax
import jax.numpy as jnp

from .kernels import block_gemm as bg
from .kernels import pattern_conv as pc
from .kernels import ref

# ---------------------------------------------------------------- SmallCNN

CNN_CLASSES = 8
CNN_IN = (3, 24, 24)  # C, H, W


def init_cnn(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    he = lambda k, shape, fan: jax.random.normal(k, shape, jnp.float32) * (2.0 / fan) ** 0.5
    return {
        "c1": he(ks[0], (16, 3, 3, 3), 27),
        "b1": jnp.zeros((16,), jnp.float32),
        "c2": he(ks[1], (32, 16, 3, 3), 144),
        "b2": jnp.zeros((32,), jnp.float32),
        "c3": he(ks[2], (32, 32, 3, 3), 288),
        "b3": jnp.zeros((32,), jnp.float32),
        "d1": he(ks[3], (32, CNN_CLASSES), 32),
        "db": jnp.zeros((CNN_CLASSES,), jnp.float32),
    }


def cnn_forward(params, x, variant="dense", masks=None):
    """Forward pass. `variant`: dense | pattern | block.

    pattern: convs run through the Pallas pattern GEMM with `masks[name]`
    (OIHW 0/1, 4-of-9 patterns). block: the classifier head runs through
    the Pallas block-sparse GEMM with masks["d1_block"].
    """

    def conv(name, x, stride):
        w = params[name]
        if variant == "pattern" and masks is not None and name in masks:
            y = pc.pattern_conv2d(x, w, masks[name], stride=stride, pad=1, bm=128, bn=32, bk=32)
        else:
            y = ref.conv2d_nchw(x, w, stride=stride, pad=1)
        b = params["b" + name[1]]
        return jax.nn.relu(y + b[None, :, None, None])

    x = conv("c1", x, 1)
    x = conv("c2", x, 2)
    x = conv("c3", x, 2)
    x = jnp.mean(x, axis=(2, 3))  # global average pool -> [N, 32]
    if variant == "block" and masks is not None and "d1_block" in masks:
        logits = bg.dense_via_block_gemm(x, params["d1"], masks["d1_block"], bk=8, bn=4)
    else:
        logits = x @ params["d1"]
    return logits + params["db"]


# ------------------------------------------------------------- WDSR (tiny)

WDSR_IN = (3, 32, 32)  # upscales x2 -> (3, 64, 64)
WDSR_FEATS = 8


def init_wdsr(seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    f = WDSR_FEATS
    he = lambda k, shape, fan: jax.random.normal(k, shape, jnp.float32) * (2.0 / fan) ** 0.5
    return {
        "head": he(ks[0], (f, 3, 3, 3), 27),
        "r1a": he(ks[1], (f * 4, f, 1, 1), f),
        "r1b": he(ks[2], (f, f * 4, 3, 3), f * 36),
        "r2a": he(ks[3], (f * 4, f, 1, 1), f),
        "r2b": he(ks[4], (f, f * 4, 3, 3), f * 36),
        "up": he(ks[5], (12, f, 3, 3), f * 9),
        "skip": he(ks[6], (12, 3, 5, 5), 75),
    }


def _pixel_shuffle2(x):
    n, c, h, w = x.shape
    r = 2
    x = x.reshape(n, c // (r * r), r, r, h, w)
    return x.transpose(0, 1, 4, 2, 5, 3).reshape(n, c // (r * r), h * r, w * r)


def wdsr_forward(params, x, variant="dense", masks=None):
    def conv(name, x, pad):
        w = params[name]
        if variant == "pattern" and masks is not None and name in masks:
            return pc.pattern_conv2d(x, w, masks[name], stride=1, pad=pad, bm=128, bn=32, bk=32)
        return ref.conv2d_nchw(x, w, stride=1, pad=pad)

    t = conv("head", x, 1)
    for r in ("r1", "r2"):
        y = conv(r + "a", t, 0)
        y = jax.nn.relu(y)
        y = conv(r + "b", y, 1)
        t = t + y
    main = _pixel_shuffle2(conv("up", t, 1))
    skip = _pixel_shuffle2(ref.conv2d_nchw(x, params["skip"], stride=1, pad=2))
    return main + skip


# ------------------------------------------------- pattern mask generation

def elite8_masks(params, conv_names):
    """Assign each 3×3 kernel the best 4-entry pattern from the elite-8 set
    (center + 3 neighbours) — mirrors rust/src/pruning/pattern.rs."""
    elite = []
    for trio in ([1, 3, 0], [1, 5, 2], [3, 7, 6], [5, 7, 8],
                 [1, 3, 5], [3, 7, 5], [1, 7, 3], [1, 7, 5]):
        m = jnp.zeros((9,), jnp.float32).at[jnp.array(trio + [4])].set(1.0)
        elite.append(m.reshape(3, 3))
    pats = jnp.stack(elite)  # [8, 3, 3]
    masks = {}
    for name in conv_names:
        w = params[name]
        if w.shape[-2:] != (3, 3):
            continue
        energy = jnp.einsum("oihw,phw->oip", w * w, pats)
        best = jnp.argmax(energy, axis=-1)  # [O, I]
        masks[name] = pats[best]  # [O, I, 3, 3]
    return masks


def block_mask_for_dense(w, bk=8, bn=4, keep=0.5, seed=3):
    """Magnitude-ranked block mask for a dense matrix [K, N]."""
    k, n = w.shape
    gk, gn = (k + bk - 1) // bk, (n + bn - 1) // bn
    pad = jnp.pad(w, ((0, gk * bk - k), (0, gn * bn - n)))
    blocks = pad.reshape(gk, bk, gn, bn)
    energy = jnp.sum(blocks * blocks, axis=(1, 3))  # [gk, gn]
    kth = jnp.quantile(energy.reshape(-1), 1.0 - keep)
    return (energy >= kth).astype(jnp.float32)
