"""L1 Pallas kernel: block-sparse GEMM (the §2.1.2 block-pruning execution
path).

The pruning block grid *is* the BlockSpec tile grid: a block of `w` whose
mask bit is 0 contributes nothing, and in the kernel the contribution is
gated with `pl.when`-free arithmetic (mask multiply) so the same HLO runs
under interpret mode; on a real TPU the zero blocks' HBM→VMEM copies are
the quantity saved, which is what the structural perf notes account.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_to(v, m):
    return max(m, (v + m - 1) // m * m)


def _kernel(x_ref, w_ref, m_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    gate = m_ref[0, 0]
    o_ref[...] += gate * jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def block_gemm(x, w, block_mask, bk, bn, bm=128):
    """`x [M,K] @ w [K,N]` where `block_mask [ceil(K/bk), ceil(N/bn)]`
    zeroes pruned weight blocks. Tile sizes = pruning block sizes."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm = min(bm, _round_to(m, 8))
    kp, np_ = _round_to(k, bk), _round_to(n, bn)
    mp = _round_to(m, bm)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    gk, gn = kp // bk, np_ // bn
    mask = jnp.zeros((gk, gn), jnp.float32)
    bm_rows = min(block_mask.shape[0], gk)
    bm_cols = min(block_mask.shape[1], gn)
    mask = mask.at[:bm_rows, :bm_cols].set(
        jnp.asarray(block_mask, jnp.float32)[:bm_rows, :bm_cols]
    )
    out = pl.pallas_call(
        functools.partial(_kernel),
        grid=(mp // bm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, mask)
    return out[:m, :n]


def dense_via_block_gemm(x, w, block_mask, bk, bn):
    """Dense layer `[.., K] @ [K, N]` through the block-sparse kernel."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    y = block_gemm(x.reshape(-1, k), w, block_mask, bk, bn)
    return y.reshape(*lead, w.shape[1])
