"""L1 Pallas kernel: pattern-pruned convolution as a tiled im2col GEMM.

TPU adaptation of PatDNN's mobile-SIMD story (DESIGN.md
§Hardware-Adaptation): the 4-entry kernel patterns are folded into the
weight matrix at *pack time* (the FKW analogue), and the hot loop is a
VMEM-tiled GEMM over im2col patches — BlockSpec expresses the HBM→VMEM
schedule the paper expressed with threadblocks. Block shapes default to
MXU-friendly 128×128 tiles (shrunk for small problems).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated structurally (VMEM
footprint / MXU utilization) in DESIGN.md.
"""


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _round_to(v, m):
    return max(m, (v + m - 1) // m * m)


def pallas_gemm(x, w, bm=128, bn=128, bk=128):
    """Pallas tiled GEMM (accumulating in the output tile — valid because
    the grid's k dimension is sequential in interpret mode)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = min(bm, _round_to(m, 8))
    bn = min(bn, _round_to(n, 8))
    bk = min(bk, _round_to(k, 8))
    mp, kp, np_ = _round_to(m, bm), _round_to(k, bk), _round_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk

    def kernel(x_ref, w_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def pattern_conv2d(x, w, mask, stride=1, pad=1, bm=128, bn=128, bk=128):
    """Pattern-pruned conv: weights are packed (masked) at trace time, the
    conv executes as an im2col + Pallas tiled GEMM.

    x: [N, C, H, W]; w, mask: [O, I, KH, KW].
    """
    o, i, kh, kw = w.shape
    packed = (w * mask).reshape(o, i * kh * kw).T  # [K, O]
    patches, oh, ow = ref.im2col(x, kh, kw, stride=stride, pad=pad)
    y = pallas_gemm(patches, packed, bm=bm, bn=bn, bk=bk)  # [N*OH*OW, O]
    n = x.shape[0]
    return y.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)


def vmem_bytes(bm=128, bn=128, bk=128):
    """Structural VMEM footprint of one grid step (f32): x-tile + w-tile +
    out-tile. The perf notes in EXPERIMENTS.md §Perf track this against the
    ~16 MiB/core budget."""
    return 4 * (bm * bk + bk * bn + bm * bn)
