"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every Pallas kernel in this package is checked against these functions by
pytest (with hypothesis sweeping shapes/seeds) before anything is AOT-lowered
for the Rust runtime.
"""

import jax.numpy as jnp
from jax import lax


def conv2d_nchw(x, w, stride=1, pad=1):
    """Reference NCHW/OIHW conv via lax.conv_general_dilated."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def pattern_conv2d(x, w, mask, stride=1, pad=1):
    """Pattern-pruned conv: `mask` (OIHW {0,1}) encodes the per-kernel
    4-entry patterns; semantics are conv with the masked weights."""
    return conv2d_nchw(x, w * mask, stride=stride, pad=pad)


def block_gemm(x, w, block_mask, bk, bn):
    """Block-sparse GEMM: x [M,K] @ (w [K,N] masked by block_mask
    [K//bk, N//bn])."""
    k, n = w.shape
    mask = jnp.repeat(jnp.repeat(block_mask, bk, axis=0), bn, axis=1)
    mask = mask[:k, :n]
    return x @ (w * mask)


def im2col(x, kh, kw, stride=1, pad=1):
    """Unfold NCHW into [N*OH*OW, C*KH*KW] patches (GEMM formulation)."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            patch = xp[:, :, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride]
            cols.append(patch.reshape(n, c, oh * ow))
    # [n, c, kh*kw, oh*ow] -> [n*oh*ow, c*kh*kw]
    stacked = jnp.stack(cols, axis=2)
    return stacked.transpose(0, 3, 1, 2).reshape(n * oh * ow, c * kh * kw), oh, ow
