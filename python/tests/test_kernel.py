"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py), with
hypothesis sweeping shapes — the core correctness signal gating AOT."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_gemm as bg
from compile.kernels import pattern_conv as pc
from compile.kernels import ref


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_pallas_gemm_matches_jnp(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    got = pc.pallas_gemm(x, w, bm=32, bn=32, bk=32)
    want = x @ w
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 6),
    o=st.integers(1, 10),
    hw=st.integers(4, 14),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_pattern_conv_matches_ref(n, c, o, hw, stride, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, c, hw, hw)
    w = rand(rng, o, c, 3, 3)
    # Random 4-of-9 patterns per kernel.
    masks = np.zeros((o, c, 9), np.float32)
    for i in range(o):
        for j in range(c):
            masks[i, j, rng.choice(9, 4, replace=False)] = 1.0
    mask = jnp.asarray(masks.reshape(o, c, 3, 3))
    got = pc.pattern_conv2d(x, w, mask, stride=stride, pad=1, bm=32, bn=16, bk=16)
    want = ref.pattern_conv2d(x, w, mask, stride=stride, pad=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    gk=st.integers(1, 5),
    gn=st.integers(1, 5),
    bk=st.sampled_from([4, 8]),
    bn=st.sampled_from([4, 8]),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_block_gemm_matches_ref(m, gk, gn, bk, bn, density, seed):
    rng = np.random.default_rng(seed)
    k, n = gk * bk, gn * bn
    x, w = rand(rng, m, k), rand(rng, k, n)
    mask = jnp.asarray((rng.random((gk, gn)) < density).astype(np.float32))
    got = bg.block_gemm(x, w, mask, bk=bk, bn=bn, bm=32)
    want = ref.block_gemm(x, w, mask, bk=bk, bn=bn)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_block_gemm_all_masked_is_zero():
    rng = np.random.default_rng(0)
    x, w = rand(rng, 8, 16), rand(rng, 16, 8)
    mask = jnp.zeros((2, 2), jnp.float32)
    got = bg.block_gemm(x, w, mask, bk=8, bn=4, bm=8)
    assert float(jnp.abs(got).max()) == 0.0


def test_im2col_shapes():
    rng = np.random.default_rng(1)
    x = rand(rng, 2, 3, 8, 8)
    patches, oh, ow = ref.im2col(x, 3, 3, stride=2, pad=1)
    assert (oh, ow) == (4, 4)
    assert patches.shape == (2 * 16, 27)


def test_vmem_budget_of_default_tiles():
    # 128x128x128 f32 tiles: 3 * 64 KiB*... must stay well under 16 MiB.
    assert pc.vmem_bytes(128, 128, 128) < 16 * 1024 * 1024
