"""L2 correctness: model variants agree (pattern path == masked dense path)
and shapes are what the Rust runtime expects."""

import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.kernels import ref


def test_cnn_shapes():
    p = M.init_cnn(0)
    x = jnp.zeros((2, *M.CNN_IN), jnp.float32)
    y = M.cnn_forward(p, x)
    assert y.shape == (2, M.CNN_CLASSES)


def test_cnn_pattern_variant_matches_masked_dense():
    p = M.init_cnn(0)
    masks = M.elite8_masks(p, ["c1", "c2", "c3"])
    pp = {k: (v * masks[k] if k in masks else v) for k, v in p.items()}
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, *M.CNN_IN)), jnp.float32)
    a = M.cnn_forward(pp, x, variant="dense")
    b = M.cnn_forward(pp, x, variant="pattern", masks=masks)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_elite8_masks_are_4_of_9_with_center():
    p = M.init_cnn(0)
    masks = M.elite8_masks(p, ["c1"])
    m = np.asarray(masks["c1"])
    sums = m.reshape(-1, 9).sum(-1)
    assert (sums == 4).all()
    assert (m[:, :, 1, 1] == 1).all(), "elite patterns keep the center"


def test_block_variant_matches_masked_head():
    p = M.init_cnn(0)
    bmask = M.block_mask_for_dense(p["d1"], bk=8, bn=4, keep=0.5)
    masks = {"d1_block": bmask}
    x = jnp.asarray(np.random.default_rng(1).normal(size=(3, *M.CNN_IN)), jnp.float32)
    got = M.cnn_forward(p, x, variant="block", masks=masks)
    # Oracle: expand the block mask and mask the head manually.
    k, n = p["d1"].shape
    mask_full = np.repeat(np.repeat(np.asarray(bmask), 8, 0), 4, 1)[:k, :n]
    pp = dict(p)
    pp["d1"] = p["d1"] * mask_full
    want = M.cnn_forward(pp, x, variant="dense")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_wdsr_upscales_2x():
    p = M.init_wdsr(1)
    x = jnp.zeros((1, *M.WDSR_IN), jnp.float32)
    y = M.wdsr_forward(p, x)
    assert y.shape == (1, 3, M.WDSR_IN[1] * 2, M.WDSR_IN[2] * 2)


def test_wdsr_pattern_variant_matches_masked_dense():
    p = M.init_wdsr(1)
    masks = M.elite8_masks(p, ["r1b", "r2b"])
    pp = {k: (v * masks[k] if k in masks else v) for k, v in p.items()}
    x = jnp.asarray(np.random.default_rng(2).normal(size=(1, *M.WDSR_IN)), jnp.float32)
    a = M.wdsr_forward(pp, x, variant="dense")
    b = M.wdsr_forward(pp, x, variant="pattern", masks=masks)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=2e-4)


def test_pattern_pruning_preserves_information_enough_for_separation():
    # Sanity: masked conv still produces class-separable features on the
    # synthetic corpus (full accuracy check happens in train.py).
    from compile import train as T

    xs, ys = T.make_dataset(64, seed=5)
    p = M.init_cnn(0)
    masks = M.elite8_masks(p, ["c1", "c2", "c3"])
    pp = {k: (v * masks[k] if k in masks else v) for k, v in p.items()}
    logits = M.cnn_forward(pp, xs, variant="dense")
    assert bool(jnp.isfinite(logits).all())
    assert float(jnp.std(logits)) > 1e-3
