//! Use case I (§5): real-time car-model classification in a smartphone
//! app. Cost-model comparison vs the mainstream frameworks (paper: 2×–
//! 3.33× at unchanged accuracy), plus a **real** batched classification
//! stream served from compiled sessions — no AOT artifacts needed: the
//! session API executes the demo CNN in-process behind the
//! dynamic-batching `Server`.

use std::time::Duration;

use xgen::api::Compiler;
use xgen::baselines::{DeviceClass, Framework};
use xgen::coordinator::Server;
use xgen::cost::devices;
use xgen::pruning::PruneScheme;
use xgen::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("car classification (EfficientNet-B0 class backbone) on mobile GPU\n");
    let dev = devices::s10_gpu();
    // One dense session answers every baseline estimate.
    let dense = Compiler::for_model("efficientnet-b0", 1)?.compile()?;
    let mut rows = Vec::new();
    for fw in [Framework::Mnn, Framework::TfLite, Framework::Tvm] {
        if let Some(ms) = dense.estimate(&dev, fw, DeviceClass::MobileGpu) {
            rows.push((fw.name(), ms));
        }
    }
    let xg = Compiler::for_model("efficientnet-b0", 1)?
        .random_weights(5)
        .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.35 })
        .target(devices::s10_gpu())
        .compile()?;
    let x_ms = xg.estimate_target(Framework::XGenFull, DeviceClass::MobileGpu).unwrap();
    for (name, ms) in &rows {
        println!("  {name:>8}: {ms:6.1} ms   ({:.2}x vs XGen)", ms / x_ms);
    }
    println!("  {:>8}: {x_ms:6.1} ms   paper band: 2x-3.33x", "XGen");

    // Real on-device classification stream: the deployed classifier is a
    // compiled session pair (batch-1 + batch-4), served with dynamic
    // batching entirely in Rust.
    println!("\nreal classification stream (compiled sessions, demo CNN):");
    let build = |batch: usize| -> anyhow::Result<xgen::api::CompiledModel> {
        Compiler::for_model("demo-cnn", batch)?
            .random_weights(5)
            .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.35 })
            .compile()
    };
    let single = build(1)?;
    let per: usize = single.input_shapes()[0].iter().product();
    let server = Server::start_compiled(single, build(4)?, Duration::from_millis(2))?;
    let mut rng = Rng::new(5);
    let frames = 64;
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..frames)
        .map(|_| server.submit((0..per).map(|_| rng.f32()).collect()))
        .collect();
    let mut counts = [0usize; 8];
    for rx in rxs {
        let logits = rx.recv().unwrap().map_err(anyhow::Error::msg)?;
        let cls = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        counts[cls] += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = server.stats();
    println!(
        "  {frames} frames in {:.1} ms ({:.0} FPS, mean batch {:.2}), class histogram {:?}",
        wall * 1e3,
        frames as f64 / wall,
        st.mean_batch(),
        counts
    );
    Ok(())
}
