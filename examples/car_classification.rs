//! Use case I (§5): real-time car-model classification in a smartphone
//! app. Cost-model comparison vs the mainstream frameworks (paper: 2×–
//! 3.33× at unchanged accuracy), plus — with artifacts built — real
//! batched classification through the PJRT runtime using the demo CNN as
//! the deployed classifier.

use std::time::Duration;

use xgen::baselines::{DeviceClass, Framework};
use xgen::coordinator::{compile, Server};
use xgen::cost::devices;
use xgen::graph::zoo::by_name;
use xgen::graph::WeightStore;
use xgen::pruning::PruneScheme;
use xgen::runtime::{artifacts_present, default_artifact_dir};
use xgen::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("car classification (EfficientNet-B0 class backbone) on mobile GPU\n");
    let dev = devices::s10_gpu();
    let mut rows = Vec::new();
    for fw in [Framework::Mnn, Framework::TfLite, Framework::Tvm] {
        let lat = compile(by_name("efficientnet-b0", 1), None, PruneScheme::None)
            .latency_ms(&dev, fw, DeviceClass::MobileGpu);
        if let Some(ms) = lat {
            rows.push((fw.name(), ms));
        }
    }
    let mut rng = Rng::new(5);
    let g = by_name("efficientnet-b0", 1);
    let mut ws = WeightStore::init_random(&g, &mut rng);
    let xg = compile(g, Some(&mut ws), PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.35 });
    let x_ms = xg.latency_ms(&dev, Framework::XGenFull, DeviceClass::MobileGpu).unwrap();
    for (name, ms) in &rows {
        println!("  {name:>8}: {ms:6.1} ms   ({:.2}x vs XGen)", ms / x_ms);
    }
    println!("  {:>8}: {x_ms:6.1} ms   paper band: 2x-3.33x", "XGen");

    if artifacts_present() {
        println!("\nreal on-device classification stream (PJRT, demo CNN):");
        let server = Server::start(
            default_artifact_dir(),
            "cnn_dense_b1",
            "cnn_dense_b4",
            Duration::from_millis(2),
        )?;
        let per = 3 * 24 * 24;
        let frames = 64;
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..frames)
            .map(|_| server.submit((0..per).map(|_| rng.f32()).collect()))
            .collect();
        let mut counts = [0usize; 8];
        for rx in rxs {
            let logits = rx.recv().unwrap().map_err(anyhow::Error::msg)?;
            let cls = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            counts[cls] += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  {frames} frames in {:.1} ms ({:.0} FPS), class histogram {:?}",
            wall * 1e3,
            frames as f64 / wall,
            counts
        );
    }
    Ok(())
}
