//! §3.2.3: Level-4 autonomous driving on a $700 Jetson-class board — the
//! XEngine runtime demo. Simulates the Fig 16 application under all five
//! scheduling regimes of Table 5 and prints the per-module latency table.
//! The perception workload the scheduler places is sized by compiling the
//! detection model through the session API and asking the cost model.
//!
//! ```bash
//! cargo run --release --example autonomous_driving [ADy416]
//! ```

use xgen::api::Compiler;
use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::devices;
use xgen::pruning::PruneScheme;
use xgen::xengine::adapp::{modules, variants};
use xgen::xengine::sim::simulate;
use xgen::xengine::Policy;

fn main() -> anyhow::Result<()> {
    // The detection backbone the perception module runs: one compiled
    // session, estimated on the board's GPU-class unit.
    let det = Compiler::for_model("yolo-v4", 1)?
        .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 })
        .target(devices::jetson_gpu())
        .compile()?;
    if let Some(ms) = det.estimate_target(Framework::XGenFull, DeviceClass::MobileGpu) {
        println!(
            "perception backbone (YOLO-v4, pattern-pruned, cost model on jetson-gpu): {ms:.1} ms/frame\n"
        );
    }

    let want = std::env::args().nth(1);
    for v in variants() {
        if let Some(w) = &want {
            if v.name != *w {
                continue;
            }
        }
        println!("=== {} (Jetson-AGX-class board: 4 CPU cores, GPU, 2 DLAs) ===", v.name);
        let mods = modules(v);
        for p in Policy::all() {
            let r = simulate(v.name, &mods, p, 5000.0, 0xAD);
            println!("\n{}", p.name());
            for m in &r.modules {
                if m.name == "percept_postproc" {
                    continue;
                }
                if m.timed_out() {
                    println!("  {:<14} ∞ (deadlock)", m.name);
                } else {
                    let star = if m.miss_rate() > 0.5 { "*" } else { " " };
                    println!(
                        " {star}{:<14} {:7.1} ± {:5.1} ms   miss {:5.1}%",
                        m.name,
                        m.mean(),
                        m.std(),
                        m.miss_rate() * 100.0
                    );
                }
            }
            println!("  => application miss rate: {:.0}%", r.worst_miss_rate() * 100.0);
        }
        println!();
        if want.is_none() {
            break; // default: first variant only (use an arg for others)
        }
    }
    println!("(compare against Table 5 in EXPERIMENTS.md; `xgen sched --variant all` sweeps everything)");
    Ok(())
}
