//! §3.2.3: Level-4 autonomous driving on a $700 Jetson-class board — the
//! XEngine runtime demo. Simulates the Fig 16 application under all five
//! scheduling regimes of Table 5 and prints the per-module latency table.
//!
//! ```bash
//! cargo run --release --example autonomous_driving [ADy416]
//! ```

use xgen::xengine::adapp::{modules, variants};
use xgen::xengine::sim::simulate;
use xgen::xengine::Policy;

fn main() {
    let want = std::env::args().nth(1);
    for v in variants() {
        if let Some(w) = &want {
            if v.name != *w {
                continue;
            }
        }
        println!("=== {} (Jetson-AGX-class board: 4 CPU cores, GPU, 2 DLAs) ===", v.name);
        let mods = modules(v);
        for p in Policy::all() {
            let r = simulate(v.name, &mods, p, 5000.0, 0xAD);
            println!("\n{}", p.name());
            for m in &r.modules {
                if m.name == "percept_postproc" {
                    continue;
                }
                if m.timed_out() {
                    println!("  {:<14} ∞ (deadlock)", m.name);
                } else {
                    let star = if m.miss_rate() > 0.5 { "*" } else { " " };
                    println!(
                        " {star}{:<14} {:7.1} ± {:5.1} ms   miss {:5.1}%",
                        m.name,
                        m.mean(),
                        m.std(),
                        m.miss_rate() * 100.0
                    );
                }
            }
            println!("  => application miss rate: {:.0}%", r.worst_miss_rate() * 100.0);
        }
        println!();
        if want.is_none() {
            break; // default: first variant only (use an arg for others)
        }
    }
    println!("(compare against Table 5 in EXPERIMENTS.md; `xgen sched --variant all` sweeps everything)");
}
