//! Use case II (§5): home safety monitor — real-time activity recognition
//! with S3D (3-D convolutions). Only PyTorch Mobile could run this among
//! the baselines; XGen's block-pruning generalization to 3-D convolutions
//! (§2.1.2, Fig 7) plus fusion makes it real-time (paper: 22.6× speedup,
//! 18.31 ms/frame). All estimates go through one compiled session per
//! configuration.

use xgen::api::Compiler;
use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::devices;
use xgen::graph::zoo::by_name;
use xgen::pruning::PruneScheme;

fn main() -> anyhow::Result<()> {
    let gpu = devices::s10_gpu();
    let cpu = devices::s10_cpu();
    println!("S3D activity recognition (16-frame clips) on Galaxy-S10-class device\n");

    // Which baselines can run a 3-D conv model at all? (Table 3's dashes.)
    let g = by_name("s3d", 1);
    for fw in [Framework::Mnn, Framework::Tvm, Framework::TfLite, Framework::PyTorchMobile] {
        let ok = fw.supports(&g, DeviceClass::MobileCpu);
        println!(
            "  {:>10} runs S3D on mobile CPU: {}",
            fw.name(),
            if ok { "yes" } else { "NO (unsupported ops)" }
        );
    }

    // PyTorch Mobile (the only working baseline) vs XGen.
    let pt = Compiler::for_model("s3d", 1)?
        .compile()?
        .estimate(&cpu, Framework::PyTorchMobile, DeviceClass::MobileCpu)
        .unwrap();
    // XGen: block pruning (the 3-D generalization) + universal fusion.
    let xc = Compiler::for_model("s3d", 1)?
        .random_weights(3)
        .scheme(PruneScheme::Block { block: 8, rate: 0.8 })
        .compile()?;
    let x_cpu = xc.estimate(&cpu, Framework::XGenFull, DeviceClass::MobileCpu).unwrap();
    let x_gpu = xc.estimate(&gpu, Framework::XGenFull, DeviceClass::MobileGpu).unwrap();
    if let Some(r) = &xc.report().prune {
        println!(
            "\n  XGen 3-D block pruning: {:.0}% sparsity, effective {:.1} GMACs",
            r.sparsity * 100.0,
            r.effective_macs as f64 / 1e9
        );
    }
    println!("\n  PyTorch Mobile (CPU): {pt:8.0} ms / clip");
    println!(
        "  XGen (CPU)          : {x_cpu:8.0} ms / clip   ({:.1}x)",
        pt / x_cpu
    );
    println!(
        "  XGen (GPU)          : {x_gpu:8.0} ms / clip   ({:.1}x)   paper: 22.6x",
        pt / x_gpu
    );
    let per_frame = x_gpu / 16.0;
    println!(
        "\n  per-frame: {per_frame:.1} ms -> {}",
        if per_frame < 40.0 {
            "REAL-TIME activity recognition feasible (paper: 18.31 ms/frame)"
        } else {
            "not real-time"
        }
    );
    Ok(())
}
