//! Use case III (§5): real-time super resolution. A WDSR-style ×2
//! upscaler runs through the PJRT runtime in dense and pattern-pruned
//! forms; we report FPS and the PSNR between the two outputs, plus the
//! paper-scale WDSR-b cost-model comparison vs TFLite (paper: 1.9×
//! compiler-only, 7.2× with pruning; 5 → 36 FPS).

use xgen::baselines::{DeviceClass, Framework};
use xgen::coordinator::compile;
use xgen::cost::devices;
use xgen::graph::zoo::by_name;
use xgen::pruning::PruneScheme;
use xgen::runtime::{artifacts_present, default_artifact_dir, ModelRuntime};
use xgen::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // Paper-scale comparison on the cost model (Galaxy S10 GPU).
    let dev = devices::s10_gpu();
    let tflite = compile(by_name("wdsr-b", 1), None, PruneScheme::None)
        .latency_ms(&dev, Framework::TfLite, DeviceClass::MobileGpu)
        .unwrap();
    let xgen_dense = compile(by_name("wdsr-b", 1), None, PruneScheme::None)
        .latency_ms(&dev, Framework::XGenFull, DeviceClass::MobileGpu)
        .unwrap();
    let xgen_pruned = compile(
        by_name("wdsr-b", 1),
        None,
        PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 },
    )
    .latency_ms(&dev, Framework::XGenFull, DeviceClass::MobileGpu)
    .unwrap();
    println!("WDSR-b on mobile GPU (cost model, 360p -> 720p):");
    println!("  TFLite            : {:6.1} ms  ({:.1} FPS)", tflite, 1000.0 / tflite);
    println!(
        "  XGen compiler-only: {:6.1} ms  ({:.1} FPS, {:.1}x)",
        xgen_dense,
        1000.0 / xgen_dense,
        tflite / xgen_dense
    );
    println!(
        "  XGen + pruning    : {:6.1} ms  ({:.1} FPS, {:.1}x)   paper: 7.2x, 5->36 FPS",
        xgen_pruned,
        1000.0 / xgen_pruned,
        tflite / xgen_pruned
    );

    if !artifacts_present() {
        println!("\n(run `make artifacts` for the real PJRT upscaling demo)");
        return Ok(());
    }
    // Real execution: upscale a synthetic 32x32 image.
    let mut rt = ModelRuntime::open(default_artifact_dir())?;
    let mut rng = Rng::new(11);
    let n: usize = rt.load("wdsr_b1")?.input_shape.iter().product();
    // Smooth "image": sinusoids + noise.
    let x: Vec<f32> = (0..n)
        .map(|i| ((i % 32) as f32 / 5.0).sin() * 0.4 + 0.5 + rng.f32() * 0.05)
        .collect();
    let reps = 20;
    let t0 = std::time::Instant::now();
    let mut dense_out = Vec::new();
    for _ in 0..reps {
        dense_out = rt.load("wdsr_b1")?.run(&x)?;
    }
    let dense_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    let t0 = std::time::Instant::now();
    let mut pruned_out = Vec::new();
    for _ in 0..reps {
        pruned_out = rt.load("wdsr_pattern_b1")?.run(&x)?;
    }
    let pruned_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    // PSNR between dense and pruned upscales.
    let mse: f64 = dense_out
        .iter()
        .zip(&pruned_out)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / dense_out.len() as f64;
    let psnr = 10.0 * (1.0 / mse.max(1e-12)).log10();
    println!("\nreal PJRT execution (32x32 -> 64x64, CPU):");
    println!("  dense  : {dense_ms:.2} ms/frame ({:.0} FPS)", 1000.0 / dense_ms);
    println!("  pattern: {pruned_ms:.2} ms/frame ({:.0} FPS)", 1000.0 / pruned_ms);
    println!("  dense-vs-pattern PSNR: {psnr:.1} dB over {} px", dense_out.len());
    Ok(())
}
