//! Use case III (§5): real-time super resolution. Paper-scale WDSR-b
//! cost-model comparison vs TFLite (paper: 1.9× compiler-only, 7.2× with
//! pruning; 5 → 36 FPS), plus **real execution**: a WDSR-style ×2
//! upscaler compiled dense and pattern-pruned through the session API —
//! the pruned session runs its convs on auto-attached FKW kernels — with
//! FPS and the PSNR between the two outputs.

use xgen::api::Compiler;
use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::devices;
use xgen::graph::zoo::NetBuilder;
use xgen::graph::{Act, Graph};
use xgen::pruning::PruneScheme;
use xgen::tensor::Tensor;

/// Tiny WDSR-style ×2 upscaler (32×32 → 64×64) for real execution.
fn sr_mini() -> Graph {
    let mut b = NetBuilder::new("sr-mini", &[1, 3, 32, 32]);
    b.conv(16, 3, 1, 1, 1);
    b.act(Act::Relu);
    b.conv(16, 3, 1, 1, 1);
    b.act(Act::Relu);
    b.conv(12, 3, 1, 1, 1); // 3 * r^2 channels, r = 2
    b.pixel_shuffle(2);
    b.finish()
}

fn main() -> anyhow::Result<()> {
    // Paper-scale comparison on the cost model (Galaxy S10 GPU).
    let dev = devices::s10_gpu();
    let tflite = Compiler::for_model("wdsr-b", 1)?
        .compile()?
        .estimate(&dev, Framework::TfLite, DeviceClass::MobileGpu)
        .unwrap();
    let xgen_dense = Compiler::for_model("wdsr-b", 1)?
        .compile()?
        .estimate(&dev, Framework::XGenFull, DeviceClass::MobileGpu)
        .unwrap();
    let xgen_pruned = Compiler::for_model("wdsr-b", 1)?
        .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 })
        .compile()?
        .estimate(&dev, Framework::XGenFull, DeviceClass::MobileGpu)
        .unwrap();
    println!("WDSR-b on mobile GPU (cost model, 360p -> 720p):");
    println!("  TFLite            : {:6.1} ms  ({:.1} FPS)", tflite, 1000.0 / tflite);
    println!(
        "  XGen compiler-only: {:6.1} ms  ({:.1} FPS, {:.1}x)",
        xgen_dense,
        1000.0 / xgen_dense,
        tflite / xgen_dense
    );
    println!(
        "  XGen + pruning    : {:6.1} ms  ({:.1} FPS, {:.1}x)   paper: 7.2x, 5->36 FPS",
        xgen_pruned,
        1000.0 / xgen_pruned,
        tflite / xgen_pruned
    );

    // Real execution: compile the mini upscaler dense and pattern-pruned
    // (same weight seed, so the pruned session is the dense one minus the
    // pattern-cut weights) and upscale a synthetic 32×32 image.
    let dense = Compiler::new(sr_mini()).random_weights(11).compile()?;
    let pruned = Compiler::new(sr_mini())
        .random_weights(11)
        .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 })
        .compile()?;
    println!(
        "\nreal execution (session API, 32x32 -> 64x64): {} FKW conv layers on the pruned session",
        pruned.report().fkw_layers
    );
    // Smooth "image": sinusoids, channel-shifted.
    let mut x = Tensor::zeros(&[1, 3, 32, 32]);
    for c in 0..3 {
        for y in 0..32 {
            for xx in 0..32 {
                let v = ((y as f32) / 5.0).sin() * 0.4 + ((xx as f32) / 7.0).cos() * 0.3 + 0.5
                    + c as f32 * 0.1;
                x.set(&[0, c, y, xx], v);
            }
        }
    }
    let reps = 20;
    let t0 = std::time::Instant::now();
    let mut dense_out = Vec::new();
    for _ in 0..reps {
        dense_out = dense.infer(std::slice::from_ref(&x))?;
    }
    let dense_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    let t0 = std::time::Instant::now();
    let mut pruned_out = Vec::new();
    for _ in 0..reps {
        pruned_out = pruned.infer(std::slice::from_ref(&x))?;
    }
    let pruned_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    // PSNR between dense and pruned upscales.
    let mse: f64 = dense_out[0]
        .data()
        .iter()
        .zip(pruned_out[0].data())
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / dense_out[0].len() as f64;
    let psnr = 10.0 * (1.0 / mse.max(1e-12)).log10();
    println!("  dense  : {dense_ms:.2} ms/frame ({:.0} FPS)", 1000.0 / dense_ms);
    println!("  pattern: {pruned_ms:.2} ms/frame ({:.0} FPS)", 1000.0 / pruned_ms);
    println!(
        "  dense-vs-pattern PSNR: {psnr:.1} dB over {} px",
        dense_out[0].len()
    );
    Ok(())
}
