//! Quickstart: the XGen pipeline on one model, end to end.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. builds ResNet-50 from the zoo,
//! 2. runs graph rewriting → pattern pruning (ADMM projection) → DNNFusion,
//! 3. prints latency estimates on the Galaxy-S10-class device vs baselines,
//! 4. if `make artifacts` has been run, executes the real AOT demo CNN
//!    through the PJRT runtime.

use xgen::baselines::{DeviceClass, Framework};
use xgen::coordinator::compile;
use xgen::cost::devices;
use xgen::graph::zoo::by_name;
use xgen::graph::WeightStore;
use xgen::pruning::PruneScheme;
use xgen::runtime::{artifacts_present, default_artifact_dir, ModelRuntime};
use xgen::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let g = by_name("resnet-50", 1);
    println!("model:   {}", g.summary());
    let ops = g.operator_count();

    let mut ws = WeightStore::init_random(&g, &mut rng);
    let scheme = PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 };
    let c = compile(g, Some(&mut ws), scheme);

    println!(
        "rewrite: {} -> {} ops   fusion: {} fused layers (was {} ops)",
        ops,
        c.rewrite_stats.ops_after,
        c.plan.fused_layer_count(),
        c.rewrite_stats.ops_after,
    );
    if let Some(r) = &c.prune_report {
        println!(
            "prune:   {:.1}% sparsity over {} layers, effective {:.2} GMACs",
            r.sparsity * 100.0,
            r.layers_pruned,
            r.effective_macs as f64 / 1e9
        );
    }
    let dev = devices::s10_cpu();
    println!("\nlatency on {} (cost model):", dev.name);
    for fw in [Framework::Mnn, Framework::Tvm, Framework::TfLite, Framework::XGenFull] {
        // Baselines run the dense model with their own fusion.
        let lat = if fw == Framework::XGenFull {
            c.latency_ms(&dev, fw, DeviceClass::MobileCpu)
        } else {
            let dense = by_name("resnet-50", 1);
            let dc = compile(dense, None, PruneScheme::None);
            dc.latency_ms(&dev, fw, DeviceClass::MobileCpu)
        };
        if let Some(ms) = lat {
            println!("  {:>14}: {:7.1} ms", fw.name(), ms);
        }
    }

    if artifacts_present() {
        println!("\nPJRT demo (real execution of the AOT CNN):");
        let mut rt = ModelRuntime::open(default_artifact_dir())?;
        let m = rt.load("cnn_pattern_b1")?;
        let n: usize = m.input_shape.iter().product();
        let x: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let t0 = std::time::Instant::now();
        let y = m.run(&x)?;
        println!(
            "  cnn_pattern_b1: {:?} -> {} logits in {:.2} ms",
            m.input_shape,
            y.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );
    } else {
        println!("\n(run `make artifacts` to enable the PJRT demo)");
    }
    Ok(())
}
