//! Quickstart: the XGen **session API** on two models, end to end.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. compiles the small demo CNN through [`xgen::api::Compiler`] with
//!    pattern pruning — rewrite → prune → DNNFusion → memory planning,
//!    FKW kernels auto-attached from the prune report — and runs **real
//!    inference** on the resulting [`xgen::api::CompiledModel`],
//! 2. compiles ResNet-50 the same way and prints cost-model latency on
//!    the Galaxy-S10-class device vs the baseline frameworks,
//! 3. if `make artifacts` has been run, also executes the AOT demo CNN
//!    through the PJRT runtime.
//!
//! The one object answers both questions: `infer()` executes for real,
//! `estimate()` consults the analytical cost model, `report()` shows what
//! every stage did.

use xgen::api::Compiler;
use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::devices;
use xgen::pruning::PruneScheme;
use xgen::runtime::{artifacts_present, default_artifact_dir, ModelRuntime};
use xgen::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let scheme = PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 };

    // 1. Compile the demo CNN and run real inference through the session.
    let model = Compiler::for_model("demo-cnn", 1)?
        .random_weights(42)
        .scheme(scheme.clone())
        .compile()?;
    print!("{}", model.report().summary());

    let shape = model.input_shapes()[0].clone();
    let n: usize = shape.iter().product();
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let t0 = std::time::Instant::now();
    let logits = model.infer_flat(&x)?;
    println!(
        "real inference (FKW kernels on pruned convs): {:?} -> {} logits in {:.2} ms\n",
        shape,
        logits.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // 2. Same pipeline on ResNet-50; cost-model comparison vs baselines.
    let big = Compiler::for_model("resnet-50", 1)?
        .random_weights(42)
        .scheme(scheme)
        .compile()?;
    let dev = devices::s10_cpu();
    // Baselines run the dense model with their own fusion — one dense
    // session answers all three baseline estimates.
    let dense = Compiler::for_model("resnet-50", 1)?.compile()?;
    println!("ResNet-50 latency on {} (cost model):", dev.name);
    for fw in [Framework::Mnn, Framework::Tvm, Framework::TfLite, Framework::XGenFull] {
        let session = if fw == Framework::XGenFull { &big } else { &dense };
        if let Some(ms) = session.estimate(&dev, fw, DeviceClass::MobileCpu) {
            println!("  {:>14}: {:7.1} ms", fw.name(), ms);
        }
    }

    // 3. Optional: the AOT artifact path through PJRT.
    if artifacts_present() {
        println!("\nPJRT demo (real execution of the AOT CNN):");
        let mut rt = ModelRuntime::open(default_artifact_dir())?;
        let m = rt.load("cnn_pattern_b1")?;
        let n: usize = m.input_shape.iter().product();
        let x: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let t0 = std::time::Instant::now();
        let y = m.run(&x)?;
        println!(
            "  cnn_pattern_b1: {:?} -> {} logits in {:.2} ms",
            m.input_shape,
            y.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );
    } else {
        println!("\n(run `make artifacts` to enable the PJRT demo)");
    }
    Ok(())
}
