//! **End-to-end validation driver** (recorded in EXPERIMENTS.md §E13):
//! proves the layers compose on a real small workload.
//!
//! Always runs (pure Rust, no artifacts needed):
//! * compiles the demo CNN **dense** and **pattern-pruned** through the
//!   session API (`xgen::api::Compiler`) from one weight seed,
//! * reports dense-vs-pattern top-1 agreement on random probes (the
//!   pruned session executes its convs on auto-attached FKW kernels),
//! * serves both variants through the dynamic-batching coordinator
//!   backed by compiled sessions, reporting throughput, latency
//!   percentiles and batch occupancy.
//!
//! With `make artifacts` built, additionally replays the same protocol
//! over the AOT artifacts through the PJRT runtime (L1/L2: python
//! trained, pruned and AOT-lowered the demo CNN at build time).
//!
//! ```bash
//! cargo run --release --example e2e_pipeline
//! ```

use std::time::{Duration, Instant};

use xgen::api::{CompiledModel, Compiler};
use xgen::coordinator::Server;
use xgen::pruning::PruneScheme;
use xgen::runtime::{artifacts_present, default_artifact_dir, ModelRuntime};
use xgen::util::json::Json;
use xgen::util::rng::Rng;

const REQUESTS: usize = 256;

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

fn build(batch: usize, scheme: PruneScheme) -> anyhow::Result<CompiledModel> {
    Compiler::for_model("demo-cnn", batch)?
        .random_weights(7)
        .scheme(scheme)
        .compile()
}

fn main() -> anyhow::Result<()> {
    // Dense vs pattern agreement on a fixed input set (direct sessions).
    let dense = build(1, PruneScheme::None)?;
    let pattern = build(1, PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 })?;
    println!(
        "compiled demo-cnn: dense + pattern ({} FKW conv layers, {:.0}% sparsity)",
        pattern.report().fkw_layers,
        pattern.report().prune.as_ref().map(|p| p.sparsity * 100.0).unwrap_or(0.0)
    );
    let per: usize = dense.input_shapes()[0].iter().product();
    let mut rng = Rng::new(7);
    let inputs: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..per).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect();
    let mut agree = 0;
    for x in &inputs {
        if argmax(&dense.infer_flat(x)?) == argmax(&pattern.infer_flat(x)?) {
            agree += 1;
        }
    }
    println!(
        "dense vs pattern top-1 agreement on random probes: {}/{}",
        agree,
        inputs.len()
    );

    // Batched serving of both variants through compiled sessions.
    for (label, scheme) in [
        ("dense", PruneScheme::None),
        ("pattern", PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 }),
    ] {
        let server = Server::start_compiled(
            build(1, scheme.clone())?,
            build(4, scheme)?,
            Duration::from_millis(2),
        )?;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..REQUESTS)
            .map(|_| server.submit((0..per).map(|_| rng.f32() * 2.0 - 1.0).collect()))
            .collect();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv().unwrap().is_ok() {
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let st = server.stats();
        let s = st.summary().expect("latencies recorded");
        println!(
            "[{label:>7}] {ok}/{REQUESTS} ok in {:6.1} ms | {:7.0} req/s | mean batch {:4.2} | p50 {:6.2} ms | p95 {:6.2} ms",
            wall * 1e3,
            ok as f64 / wall,
            st.mean_batch(),
            s.p50,
            s.p95
        );
    }

    if !artifacts_present() {
        println!("\ne2e OK (compiled sessions). Run `make artifacts` for the PJRT replay.");
        return Ok(());
    }

    // PJRT replay over the AOT artifacts.
    let dir = default_artifact_dir();
    if let Ok(text) = std::fs::read_to_string(dir.join("accuracy.json")) {
        if let Ok(acc) = Json::parse(&text) {
            println!("\nmeasured accuracy (python training, synthetic 8-class corpus):");
            if let Some(obj) = acc.as_obj() {
                for (k, v) in obj {
                    println!("  {:>15}: {:.3}", k, v.as_f64().unwrap_or(0.0));
                }
            }
        }
    }
    let mut rt = ModelRuntime::open(&dir)?;
    let per: usize = rt.load("cnn_dense_b1")?.input_shape[1..].iter().product();
    let mut agree = 0;
    let probes: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..per).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect();
    for x in &probes {
        let d = rt.load("cnn_dense_b1")?.run(x)?;
        let p = rt.load("cnn_pattern_b1")?.run(x)?;
        if argmax(&d) == argmax(&p) {
            agree += 1;
        }
    }
    println!(
        "\nPJRT dense vs pattern top-1 agreement: {}/{}",
        agree,
        probes.len()
    );
    drop(rt);
    for artifact in ["cnn_dense", "cnn_pattern"] {
        let server = Server::start(
            dir.clone(),
            &format!("{artifact}_b1"),
            &format!("{artifact}_b4"),
            Duration::from_millis(2),
        )?;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..REQUESTS)
            .map(|_| server.submit((0..per).map(|_| rng.f32() * 2.0 - 1.0).collect()))
            .collect();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv().unwrap().is_ok() {
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let st = server.stats();
        let s = st.summary().expect("latencies recorded");
        println!(
            "[PJRT {artifact}] {ok}/{REQUESTS} ok in {:6.1} ms | {:7.0} req/s | mean batch {:4.2} | p50 {:6.2} ms | p95 {:6.2} ms",
            wall * 1e3,
            ok as f64 / wall,
            st.mean_batch(),
            s.p50,
            s.p95
        );
    }
    println!("\ne2e OK: compiled sessions and AOT artifacts both served.");
    Ok(())
}
