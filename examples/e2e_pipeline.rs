//! **End-to-end validation driver** (recorded in EXPERIMENTS.md §E13):
//! proves all three layers compose on a real small workload.
//!
//! * L1/L2 (build time): `make artifacts` trained the demo CNN on the
//!   synthetic shape corpus, pattern-pruned + fine-tuned it, and AOT-lowered
//!   dense + pattern variants (the pattern variant goes through the Pallas
//!   pattern-GEMM kernel) to HLO text.
//! * L3 (this binary): loads both artifacts through the PJRT CPU client and
//!   serves a batched request stream with the dynamic-batching coordinator,
//!   reporting throughput, latency percentiles, batch occupancy, and
//!   dense-vs-pattern prediction agreement, plus the measured training
//!   accuracies from artifacts/accuracy.json.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::time::{Duration, Instant};

use xgen::coordinator::Server;
use xgen::runtime::{artifacts_present, default_artifact_dir, ModelRuntime};
use xgen::util::json::Json;
use xgen::util::rng::Rng;

const REQUESTS: usize = 256;

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

fn main() -> anyhow::Result<()> {
    if !artifacts_present() {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    }
    let dir = default_artifact_dir();

    // Measured training accuracies (python/compile/train.py).
    if let Ok(text) = std::fs::read_to_string(dir.join("accuracy.json")) {
        if let Ok(acc) = Json::parse(&text) {
            println!("measured accuracy (python training, synthetic 8-class corpus):");
            if let Some(obj) = acc.as_obj() {
                for (k, v) in obj {
                    println!("  {:>15}: {:.3}", k, v.as_f64().unwrap_or(0.0));
                }
            }
        }
    }

    // Dense vs pattern agreement on a fixed input set (direct runtime).
    let mut rt = ModelRuntime::open(&dir)?;
    let per: usize = rt.load("cnn_dense_b1")?.input_shape[1..].iter().product();
    let mut rng = Rng::new(7);
    let inputs: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..per).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect();
    let mut agree = 0;
    for x in &inputs {
        let d = rt.load("cnn_dense_b1")?.run(x)?;
        let p = rt.load("cnn_pattern_b1")?.run(x)?;
        if argmax(&d) == argmax(&p) {
            agree += 1;
        }
    }
    println!(
        "\ndense vs pattern top-1 agreement on random probes: {}/{}",
        agree,
        inputs.len()
    );
    drop(rt);

    // Batched serving of both variants.
    for artifact in ["cnn_dense", "cnn_pattern"] {
        let server = Server::start(
            dir.clone(),
            &format!("{artifact}_b1"),
            &format!("{artifact}_b4"),
            Duration::from_millis(2),
        )?;
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..REQUESTS)
            .map(|_| server.submit((0..per).map(|_| rng.f32() * 2.0 - 1.0).collect()))
            .collect();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv().unwrap().is_ok() {
                ok += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let st = server.stats();
        let s = st.summary().expect("latencies recorded");
        println!(
            "\n[{artifact}] {ok}/{REQUESTS} ok in {:6.1} ms | {:7.0} req/s | mean batch {:4.2} | p50 {:6.2} ms | p95 {:6.2} ms",
            wall * 1e3,
            ok as f64 / wall,
            st.mean_batch(),
            s.p50,
            s.p95
        );
    }
    println!("\ne2e OK: python built the artifacts once; Rust served everything.");
    Ok(())
}
